//! Prometheus text-exposition renderer.
//!
//! Renders a [`crate::coordinator::metrics::Metrics`] JSON snapshot —
//! *not* the registry's internals, so the exporter and the registry
//! evolve independently — into the Prometheus text format (version
//! 0.0.4): `# HELP`/`# TYPE` headers, counters, gauges, summaries with
//! quantile labels for the windowed histograms, and real cumulative
//! `_bucket{le="…"}` series for fixed-bucket histograms. Reachable as
//! the `metrics_prom` wire op and `grpot metrics --format prom`.

use crate::jsonlite::Value;
use std::fmt::Write as _;

/// Prefix stamped on every exported metric name.
const PREFIX: &str = "grpot_";

/// Sanitize a dotted metric name into a Prometheus identifier:
/// `serve.solve_seconds` → `grpot_serve_solve_seconds`.
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(PREFIX.len() + name.len());
    out.push_str(PREFIX);
    for (i, ch) in name.chars().enumerate() {
        let ok = ch.is_ascii_alphanumeric() || ch == '_' || ch == ':';
        let ok = ok && !(i == 0 && ch.is_ascii_digit());
        out.push(if ok { ch } else { '_' });
    }
    out
}

/// Split a metric name into its sanitized base and a pass-through label
/// block: `serve.breaker_state{dataset="k"}` →
/// (`grpot_serve_breaker_state`, `{dataset="k"}`). Label blocks are
/// composed by trusted in-process callers (values escaped at the call
/// site), so they pass through verbatim instead of being mangled to
/// underscores like ordinary name characters.
fn prom_series(name: &str) -> (String, String) {
    match name.split_once('{') {
        Some((base, labels)) => (prom_name(base), format!("{{{labels}")),
        None => (prom_name(name), String::new()),
    }
}

/// Format a sample value: integers without a decimal point, +Inf as
/// Prometheus spells it.
fn prom_num(x: f64) -> String {
    if x.is_infinite() && x > 0.0 {
        "+Inf".to_string()
    } else if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn header(out: &mut String, name: &str, kind: &str, help: &str) {
    let _ = writeln!(out, "# HELP {name} {help}");
    let _ = writeln!(out, "# TYPE {name} {kind}");
}

/// Render a metrics snapshot (the exact value `Metrics::snapshot`
/// returns) as Prometheus text exposition.
pub fn render(snapshot: &Value) -> String {
    let mut out = String::new();

    // Labeled series under one base name (per-key gauges) share a
    // single HELP/TYPE header: the BTreeMap's sorted iteration keeps
    // them adjacent, so tracking the last header emitted suffices.
    if let Some(Value::Obj(counters)) = snapshot.get("counters") {
        let mut last = String::new();
        for (name, v) in counters {
            let (n, labels) = prom_series(name);
            if n != last {
                header(&mut out, &n, "counter", "grpot counter");
                last = n.clone();
            }
            let _ = writeln!(out, "{n}{labels} {}", prom_num(v.as_f64().unwrap_or(0.0)));
        }
    }

    if let Some(Value::Obj(gauges)) = snapshot.get("gauges") {
        let mut last = String::new();
        for (name, v) in gauges {
            let (n, labels) = prom_series(name);
            if n != last {
                header(&mut out, &n, "gauge", "grpot gauge");
                last = n.clone();
            }
            let _ = writeln!(out, "{n}{labels} {}", prom_num(v.as_f64().unwrap_or(0.0)));
        }
    }

    // Timers are (sum of seconds, count) pairs — a quantile-less
    // summary in Prometheus terms.
    if let Some(Value::Obj(timers)) = snapshot.get("timers") {
        for (name, v) in timers {
            let n = prom_name(&format!("{name}_seconds"));
            header(&mut out, &n, "summary", "grpot timer");
            let sum = v.get("total_s").and_then(Value::as_f64).unwrap_or(0.0);
            let count = v.get("count").and_then(Value::as_f64).unwrap_or(0.0);
            let _ = writeln!(out, "{n}_sum {}", prom_num(sum));
            let _ = writeln!(out, "{n}_count {}", prom_num(count));
        }
    }

    if let Some(Value::Obj(hists)) = snapshot.get("hists") {
        for (name, v) in hists {
            let n = prom_name(name);
            let count = v.get("count").and_then(Value::as_f64).unwrap_or(0.0);
            let sum = v.get("sum").and_then(Value::as_f64);
            match v.get("buckets").and_then(Value::as_arr) {
                // Fixed-bucket histogram: cumulative le-series.
                Some(buckets) => {
                    header(&mut out, &n, "histogram", "grpot histogram");
                    let mut cum = 0.0;
                    for b in buckets {
                        let le = b.get("le").and_then(Value::as_f64).unwrap_or(f64::INFINITY);
                        cum += b.get("count").and_then(Value::as_f64).unwrap_or(0.0);
                        let _ = writeln!(
                            out,
                            "{n}_bucket{{le=\"{}\"}} {}",
                            prom_num(le),
                            prom_num(cum)
                        );
                    }
                    let _ = writeln!(out, "{n}_sum {}", prom_num(sum.unwrap_or(0.0)));
                    let _ = writeln!(out, "{n}_count {}", prom_num(count));
                }
                // Window-only histogram: quantile summary over the
                // recent window plus the all-time count.
                None => {
                    header(&mut out, &n, "summary", "grpot summary");
                    for (label, q) in [("p50", "0.5"), ("p95", "0.95"), ("p99", "0.99")] {
                        if let Some(x) = v.get(label).and_then(Value::as_f64) {
                            let _ = writeln!(out, "{n}{{quantile=\"{q}\"}} {}", prom_num(x));
                        }
                    }
                    if let Some(s) = sum {
                        let _ = writeln!(out, "{n}_sum {}", prom_num(s));
                    }
                    let _ = writeln!(out, "{n}_count {}", prom_num(count));
                }
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_sanitized() {
        assert_eq!(prom_name("serve.solve_seconds"), "grpot_serve_solve_seconds");
        assert_eq!(prom_name("a-b c"), "grpot_a_b_c");
    }

    #[test]
    fn labeled_series_keep_their_label_block() {
        let (n, labels) = prom_series("serve.breaker_state{dataset=\"synthetic|3x4\"}");
        assert_eq!(n, "grpot_serve_breaker_state");
        assert_eq!(labels, "{dataset=\"synthetic|3x4\"}");
        let (n, labels) = prom_series("serve.queue_depth");
        assert_eq!(n, "grpot_serve_queue_depth");
        assert_eq!(labels, "");
    }

    #[test]
    fn labeled_gauges_render_under_one_header() {
        let snap = Value::obj()
            .set("counters", Value::obj())
            .set(
                "gauges",
                Value::obj()
                    .set("serve.breaker_state{dataset=\"a\"}", 1.0)
                    .set("serve.breaker_state{dataset=\"b\"}", 2.0),
            )
            .set("timers", Value::obj())
            .set("hists", Value::obj());
        let text = render(&snap);
        assert_eq!(text.matches("# TYPE grpot_serve_breaker_state gauge").count(), 1);
        assert!(text.contains("grpot_serve_breaker_state{dataset=\"a\"} 1\n"), "{text}");
        assert!(text.contains("grpot_serve_breaker_state{dataset=\"b\"} 2\n"), "{text}");
    }

    #[test]
    fn renders_counters_and_gauges() {
        let snap = Value::obj()
            .set("counters", Value::obj().set("serve.requests", 7u64))
            .set("gauges", Value::obj().set("serve.queue_depth", 2.5))
            .set("timers", Value::obj())
            .set("hists", Value::obj());
        let text = render(&snap);
        assert!(text.contains("# TYPE grpot_serve_requests counter"));
        assert!(text.contains("grpot_serve_requests 7\n"));
        assert!(text.contains("# TYPE grpot_serve_queue_depth gauge"));
        assert!(text.contains("grpot_serve_queue_depth 2.5\n"));
    }

    #[test]
    fn renders_bucketed_histogram_cumulatively() {
        let buckets = Value::Arr(vec![
            Value::obj().set("le", 0.1).set("count", 3u64),
            Value::obj().set("le", 1.0).set("count", 2u64),
            Value::obj().set("le", f64::INFINITY).set("count", 1u64),
        ]);
        let snap = Value::obj()
            .set("counters", Value::obj())
            .set("gauges", Value::obj())
            .set("timers", Value::obj())
            .set(
                "hists",
                Value::obj().set(
                    "lat",
                    Value::obj().set("count", 6u64).set("sum", 4.5).set("buckets", buckets),
                ),
            );
        let text = render(&snap);
        assert!(text.contains("# TYPE grpot_lat histogram"));
        assert!(text.contains("grpot_lat_bucket{le=\"0.1\"} 3\n"));
        assert!(text.contains("grpot_lat_bucket{le=\"1\"} 5\n"));
        assert!(text.contains("grpot_lat_bucket{le=\"+Inf\"} 6\n"));
        assert!(text.contains("grpot_lat_sum 4.5\n"));
        assert!(text.contains("grpot_lat_count 6\n"));
    }

    #[test]
    fn renders_window_histogram_as_summary() {
        let snap = Value::obj()
            .set("counters", Value::obj())
            .set("gauges", Value::obj())
            .set("timers", Value::obj().set("t", Value::obj().set("total_s", 3.0).set("count", 2u64)))
            .set(
                "hists",
                Value::obj().set("w", Value::obj().set("count", 4u64).set("p50", 1.5).set("p99", 9.0)),
            );
        let text = render(&snap);
        assert!(text.contains("# TYPE grpot_w summary"));
        assert!(text.contains("grpot_w{quantile=\"0.5\"} 1.5\n"));
        assert!(text.contains("grpot_w_count 4\n"));
        assert!(text.contains("grpot_t_seconds_sum 3\n"));
        assert!(text.contains("grpot_t_seconds_count 2\n"));
    }
}
