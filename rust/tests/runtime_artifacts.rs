//! Integration: AOT JAX/Pallas artifacts loaded via PJRT must agree
//! with the native Rust dual oracle to f64 round-off, and must drive
//! the full solver to the same optimum.
//!
//! Requires the `xla` cargo feature (the whole file compiles away
//! without it) and `make artifacts` (skipped with a notice otherwise).

#![cfg(feature = "xla")]

use grpot::linalg::Mat;
use grpot::ot::dual::{eval_dense, DualOracle, DualParams, OtProblem};
use grpot::ot::fastot::{drive, FastOtConfig};
use grpot::rng::Pcg64;
use grpot::runtime::{artifact_dir, Manifest, PjrtRuntime, XlaDualOracle};

fn have_artifacts() -> Option<Manifest> {
    match Manifest::load(&artifact_dir()) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("SKIP runtime tests: {e:#} — run `make artifacts` first");
            None
        }
    }
}

/// Uniform problem matching an artifact entry's shape.
fn problem_for(l: usize, g: usize, n: usize, seed: u64) -> OtProblem {
    let mut rng = Pcg64::new(seed);
    let m = l * g;
    let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
    let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
    OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
}

#[test]
fn xla_oracle_matches_rust_dense() {
    let Some(manifest) = have_artifacts() else { return };
    let entry = manifest
        .entries
        .iter()
        .min_by_key(|e| e.m * e.n)
        .expect("at least one artifact");
    let (l, g, n) = (entry.num_groups, entry.group_size, entry.n);
    let prob = problem_for(l, g, n, 77);
    let params = DualParams::new(0.7, 0.4);
    let runtime = PjrtRuntime::cpu().expect("pjrt cpu client");
    let mut oracle =
        XlaDualOracle::from_problem(&runtime, &prob, &params, &artifact_dir()).expect("load");

    let mut rng = Pcg64::new(5);
    for trial in 0..5 {
        let x: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.5, 0.8)).collect();
        let mut g_xla = vec![0.0; prob.dim()];
        let f_xla = oracle.eval(&x, &mut g_xla);
        let mut g_rust = vec![0.0; prob.dim()];
        let (f_rust, _) = eval_dense(&prob, &params, &x, &mut g_rust);
        assert!(
            (f_xla - f_rust).abs() <= 1e-10 * f_rust.abs().max(1.0),
            "trial {trial}: objective {f_xla} vs {f_rust}"
        );
        for (i, (a, b)) in g_xla.iter().zip(&g_rust).enumerate() {
            assert!(
                (a - b).abs() <= 1e-10,
                "trial {trial}: grad[{i}] {a} vs {b}"
            );
        }
    }
}

#[test]
fn xla_oracle_drives_solver_to_same_optimum() {
    let Some(manifest) = have_artifacts() else { return };
    let entry = manifest
        .entries
        .iter()
        .min_by_key(|e| e.m * e.n)
        .expect("artifact");
    let (l, g, n) = (entry.num_groups, entry.group_size, entry.n);
    let prob = problem_for(l, g, n, 99);
    let cfg = FastOtConfig { gamma: 0.5, rho: 0.5, ..Default::default() };

    let rust_res = grpot::ot::origin::solve_origin(&prob, &cfg);

    let runtime = PjrtRuntime::cpu().expect("pjrt");
    let params = cfg.params();
    let mut oracle =
        XlaDualOracle::from_problem(&runtime, &prob, &params, &artifact_dir()).expect("load");
    let xla_res = drive(&prob, &cfg, &mut oracle, "xla-origin");

    // Same oracle values ⇒ same trajectory up to f64 round-off; allow a
    // tiny slack since XLA may fuse reductions in a different order.
    let rel = (xla_res.dual_objective - rust_res.dual_objective).abs()
        / rust_res.dual_objective.abs().max(1.0);
    assert!(
        rel < 1e-8,
        "dual objective: xla={} rust={}",
        xla_res.dual_objective,
        rust_res.dual_objective
    );
}

#[test]
fn missing_artifact_shape_is_reported() {
    let Some(_) = have_artifacts() else { return };
    let prob = problem_for(3, 7, 11, 1); // deliberately unmatched shape
    let runtime = PjrtRuntime::cpu().expect("pjrt");
    let err = XlaDualOracle::from_problem(
        &runtime,
        &prob,
        &DualParams::new(1.0, 0.5),
        &artifact_dir(),
    )
    .err()
    .expect("expected an error for unmatched shape");
    let msg = format!("{err:#}");
    assert!(msg.contains("no artifact"), "unexpected error: {msg}");
}

#[test]
fn non_uniform_groups_rejected() {
    let Some(_) = have_artifacts() else { return };
    let cost = Mat::from_fn(3, 2, |i, j| (i + j) as f64);
    let prob = OtProblem::from_parts(
        vec![1.0 / 3.0; 3],
        vec![0.5, 0.5],
        &cost,
        &[0, 0, 1], // ragged
    );
    let runtime = PjrtRuntime::cpu().expect("pjrt");
    let err = XlaDualOracle::from_problem(
        &runtime,
        &prob,
        &DualParams::new(1.0, 0.5),
        &artifact_dir(),
    )
    .err()
    .expect("expected an error for ragged groups");
    assert!(format!("{err:#}").contains("uniform"));
}
