"""L1 correctness: Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, dtypes, hyperparameters and adversarial value
ranges (including the z ≈ tau threshold boundary); every case asserts
allclose between the kernel and ``ref.py``.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import ref
from compile.kernels.group_softthresh import grad_psi_pallas, _pick_tile


def run_pair(alpha, beta, cost, L, g, tau, lq, dtype):
    alpha = jnp.asarray(alpha, dtype)
    beta = jnp.asarray(beta, dtype)
    cost = jnp.asarray(cost, dtype)
    t_k, z_k = grad_psi_pallas(alpha, beta, cost, tau, lq, num_groups=L, group_size=g)
    t_r, z_r = ref.grad_psi_uniform(alpha, beta, cost, L, g, tau, lq)
    return (np.asarray(t_k), np.asarray(z_k)), (np.asarray(t_r), np.asarray(z_r))


shapes = st.tuples(
    st.integers(min_value=1, max_value=5),   # L
    st.integers(min_value=1, max_value=7),   # g
    st.integers(min_value=1, max_value=24),  # n
)


@settings(max_examples=60, deadline=None)
@given(
    shape=shapes,
    tau=st.floats(min_value=0.0, max_value=2.0),
    lq=st.floats(min_value=0.05, max_value=5.0),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_f64(shape, tau, lq, seed):
    L, g, n = shape
    rng = np.random.default_rng(seed)
    m = L * g
    alpha = rng.normal(size=m)
    beta = rng.normal(size=n)
    cost = rng.uniform(0.0, 1.0, size=(m, n))
    (t_k, z_k), (t_r, z_r) = run_pair(alpha, beta, cost, L, g, tau, lq, jnp.float64)
    np.testing.assert_allclose(z_k, z_r, rtol=1e-12, atol=1e-12)
    np.testing.assert_allclose(t_k, t_r, rtol=1e-12, atol=1e-12)


@settings(max_examples=25, deadline=None)
@given(
    shape=shapes,
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_kernel_matches_ref_f32(shape, seed):
    L, g, n = shape
    rng = np.random.default_rng(seed)
    m = L * g
    alpha = rng.normal(size=m).astype(np.float32)
    beta = rng.normal(size=n).astype(np.float32)
    cost = rng.uniform(0.0, 1.0, size=(m, n)).astype(np.float32)
    (t_k, z_k), (t_r, z_r) = run_pair(alpha, beta, cost, L, g, 0.5, 1.0, jnp.float32)
    np.testing.assert_allclose(z_k, z_r, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(t_k, t_r, rtol=1e-5, atol=1e-6)


def test_zero_inputs_give_zero_plan():
    # alpha = beta = 0 and nonnegative costs: f <= 0 everywhere → T = 0.
    L, g, n = 3, 4, 6
    m = L * g
    cost = np.linspace(0.0, 1.0, m * n).reshape(m, n)
    t, z = grad_psi_pallas(
        jnp.zeros(m), jnp.zeros(n), jnp.asarray(cost), 0.3, 1.0,
        num_groups=L, group_size=g,
    )
    assert np.all(np.asarray(t) == 0.0)
    assert np.all(np.asarray(z) == 0.0)


def test_threshold_boundary_exact():
    # Single group, single column, engineered so z crosses tau exactly:
    # below → 0, above → positive.
    g = 4
    alpha = jnp.asarray([0.3, 0.4, 0.0, -1.0])
    beta = jnp.asarray([0.0])
    cost = jnp.zeros((g, 1))
    z_expect = np.sqrt(0.3**2 + 0.4**2)  # = 0.5
    t_below, z = grad_psi_pallas(alpha, beta, cost, 0.5, 1.0, num_groups=1, group_size=g)
    np.testing.assert_allclose(np.asarray(z)[0, 0], z_expect, rtol=1e-15)
    assert np.all(np.asarray(t_below) == 0.0), "z == tau must give a zero group"
    t_above, _ = grad_psi_pallas(alpha, beta, cost, 0.4999, 1.0, num_groups=1, group_size=g)
    assert np.asarray(t_above)[0, 0] > 0.0


def test_scale_formula_single_group():
    # Hand-computed soft threshold.
    alpha = jnp.asarray([1.0, 2.0])
    beta = jnp.asarray([0.0])
    cost = jnp.zeros((2, 1))
    tau, lq = 1.0, 2.0
    t, z = grad_psi_pallas(alpha, beta, cost, tau, lq, num_groups=1, group_size=2)
    z0 = np.sqrt(5.0)
    scale = (z0 - tau) / (lq * z0)
    np.testing.assert_allclose(np.asarray(t)[:, 0], scale * np.array([1.0, 2.0]), rtol=1e-14)
    np.testing.assert_allclose(np.asarray(z)[0, 0], z0, rtol=1e-14)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=300))
def test_pick_tile_divides(n):
    t = _pick_tile(n)
    assert 1 <= t <= min(n, 256)
    assert n % t == 0


def test_explicit_column_tile():
    L, g, n = 2, 3, 12
    m = L * g
    rng = np.random.default_rng(0)
    alpha = rng.normal(size=m)
    beta = rng.normal(size=n)
    cost = rng.uniform(size=(m, n))
    t4, z4 = grad_psi_pallas(
        jnp.asarray(alpha), jnp.asarray(beta), jnp.asarray(cost), 0.2, 1.0,
        num_groups=L, group_size=g, column_tile=4,
    )
    t12, z12 = grad_psi_pallas(
        jnp.asarray(alpha), jnp.asarray(beta), jnp.asarray(cost), 0.2, 1.0,
        num_groups=L, group_size=g, column_tile=12,
    )
    np.testing.assert_allclose(np.asarray(t4), np.asarray(t12), rtol=1e-14)
    np.testing.assert_allclose(np.asarray(z4), np.asarray(z12), rtol=1e-14)


def test_bad_tile_rejected():
    with pytest.raises(AssertionError):
        grad_psi_pallas(
            jnp.zeros(4), jnp.zeros(5), jnp.zeros((4, 5)), 0.1, 1.0,
            num_groups=2, group_size=2, column_tile=2,
        )


@settings(max_examples=30, deadline=None)
@given(
    L=st.integers(min_value=1, max_value=4),
    g=st.integers(min_value=1, max_value=5),
    n=st.integers(min_value=1, max_value=10),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_ragged_ref_agrees_with_uniform_on_uniform_input(L, g, n, seed):
    rng = np.random.default_rng(seed)
    m = L * g
    alpha = jnp.asarray(rng.normal(size=m))
    beta = jnp.asarray(rng.normal(size=n))
    cost = jnp.asarray(rng.uniform(size=(m, n)))
    gid = jnp.asarray(np.repeat(np.arange(L), g))
    t_u, z_u = ref.grad_psi_uniform(alpha, beta, cost, L, g, 0.4, 1.3)
    t_r, z_r = ref.grad_psi_ragged(alpha, beta, cost, gid, L, 0.4, 1.3)
    np.testing.assert_allclose(np.asarray(t_u), np.asarray(t_r), rtol=1e-12)
    np.testing.assert_allclose(np.asarray(z_u), np.asarray(z_r), rtol=1e-12)
