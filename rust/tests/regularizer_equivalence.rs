//! The regularizer-trait refactor's contract, end to end:
//!
//! * Group lasso routed through the `Regularizer` trait and the
//!   `SolveOptions` entry points is *byte-equal* to the pre-trait
//!   `solve_fast_ot` / `solve_origin` paths — solution, objective,
//!   iteration counts and full `OracleStats`, across hyperparameters,
//!   thread counts and SIMD dispatch, cold and warm-started.
//! * The new conjugates (squared ℓ2, negative entropy) are consistent:
//!   their oracle gradients match central finite differences, squared
//!   ℓ2 through the trait reproduces the legacy quadratic semi-dual
//!   byte for byte, and the full-dual and semi-dual solves of the same
//!   smoothed problem agree at the optimum.
//! * `GRPOT_REG` replaces only the *unset* default: explicit selections
//!   and the legacy (pre-trait) entry points can never be re-routed.

use grpot::linalg::Mat;
use grpot::ot::dual::{DualOracle, OracleStats, OtProblem};
use grpot::ot::fastot::{self, solve_fast_ot, solve_fast_ot_from, FastOtConfig, FastOtResult};
use grpot::ot::origin::{self, solve_origin};
use grpot::ot::regularizer::{AnyRegularizer, DenseRegOracle, RegKind};
use grpot::ot::semidual::{self, solve_semidual};
use grpot::ot::solve::SolveOptions;
use grpot::pool::ParallelCtx;
use grpot::rng::Pcg64;
use grpot::simd::SimdMode;
use grpot::solvers::lbfgs::LbfgsOptions;

fn random_problem(seed: u64, l: usize, g: usize, n: usize) -> OtProblem {
    let mut rng = Pcg64::new(seed);
    let m = l * g;
    let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
    let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
    OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
}

fn assert_stats_eq(a: &OracleStats, b: &OracleStats, what: &str) {
    assert_eq!(a.evals, b.evals, "{what}: evals");
    assert_eq!(a.grads_computed, b.grads_computed, "{what}: grads_computed");
    assert_eq!(a.grads_skipped, b.grads_skipped, "{what}: grads_skipped");
    assert_eq!(a.ub_checks, b.ub_checks, "{what}: ub_checks");
    assert_eq!(a.ws_hits, b.ws_hits, "{what}: ws_hits");
    assert_eq!(a.per_eval_grads, b.per_eval_grads, "{what}: per_eval_grads");
}

fn assert_results_identical(a: &FastOtResult, b: &FastOtResult, what: &str) {
    assert_eq!(a.x, b.x, "{what}: solution bytes");
    assert_eq!(a.dual_objective, b.dual_objective, "{what}: objective");
    assert_eq!(a.iterations, b.iterations, "{what}: iterations");
    assert_eq!(a.outer_rounds, b.outer_rounds, "{what}: outer rounds");
    assert_stats_eq(&a.stats, &b.stats, what);
}

fn legacy_cfg(gamma: f64, rho: f64, threads: usize, simd: SimdMode) -> FastOtConfig {
    FastOtConfig {
        gamma,
        rho,
        threads,
        simd,
        lbfgs: LbfgsOptions { max_iters: 120, ..Default::default() },
        ..Default::default()
    }
}

fn trait_opts(gamma: f64, rho: f64, threads: usize, simd: SimdMode) -> SolveOptions {
    SolveOptions::new()
        .gamma(gamma)
        .rho(rho)
        .threads(threads)
        .simd(simd)
        .regularizer(RegKind::GroupLasso)
        .lbfgs(LbfgsOptions { max_iters: 120, ..Default::default() })
}

/// The acceptance-criterion test: the group lasso through the trait
/// (`fastot::solve` / `origin::solve` + `SolveOptions`) is byte-equal
/// to the pre-refactor entry points across (γ, ρ) hitting both the
/// skip-heavy and the dense regime, 1 and 4 threads, scalar and
/// dispatched SIMD.
#[test]
fn group_lasso_via_trait_is_byte_identical() {
    let prob = random_problem(0x9E61, 4, 4, 31);
    for (gamma, rho) in [(0.1, 0.3), (1.0, 0.5), (8.0, 0.8)] {
        for threads in [1usize, 4] {
            for simd in [SimdMode::Scalar, SimdMode::Auto] {
                let what = format!("γ={gamma} ρ={rho} threads={threads} simd={simd:?}");
                let legacy = solve_fast_ot(&prob, &legacy_cfg(gamma, rho, threads, simd));
                let traited = fastot::solve(&prob, &trait_opts(gamma, rho, threads, simd))
                    .expect("group-lasso solve");
                assert_results_identical(&legacy, &traited, &format!("fast {what}"));
                let legacy_o = solve_origin(&prob, &legacy_cfg(gamma, rho, threads, simd));
                let traited_o = origin::solve(&prob, &trait_opts(gamma, rho, threads, simd))
                    .expect("group-lasso origin solve");
                assert_results_identical(&legacy_o, &traited_o, &format!("origin {what}"));
            }
        }
    }
}

/// Warm starts through `SolveOptions::warm_start` reproduce
/// `solve_fast_ot_from` byte for byte, and a caller-provided
/// `ParallelCtx` matches the internally-built one.
#[test]
fn warm_start_and_ctx_options_match_legacy() {
    let prob = random_problem(0x9E62, 3, 4, 27);
    let mut rng = Pcg64::new(17);
    let x0: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.2, 0.3)).collect();
    let legacy =
        solve_fast_ot_from(&prob, &legacy_cfg(0.6, 0.55, 2, SimdMode::Auto), x0.clone());
    let traited = fastot::solve(
        &prob,
        &trait_opts(0.6, 0.55, 2, SimdMode::Auto).warm_start(x0.clone()),
    )
    .expect("warm solve");
    assert_results_identical(&legacy, &traited, "warm fast");
    let ctx = ParallelCtx::new(2);
    let with_ctx = fastot::solve(
        &prob,
        &trait_opts(0.6, 0.55, 1, SimdMode::Auto).ctx(ctx).warm_start(x0),
    )
    .expect("ctx solve");
    assert_results_identical(&legacy, &with_ctx, "ctx fast");
}

/// A wrong-length warm start is a structured error, not a panic.
#[test]
fn bad_warm_start_length_is_an_error() {
    let prob = random_problem(0x9E63, 2, 3, 11);
    let e = fastot::solve(
        &prob,
        &trait_opts(0.5, 0.5, 1, SimdMode::Auto).warm_start(vec![0.0; 3]),
    )
    .unwrap_err();
    assert!(e.0.contains("warm-start"), "{e}");
    let e = semidual::solve(
        &prob,
        &SolveOptions::new()
            .gamma(0.5)
            .regularizer(RegKind::SquaredL2)
            .warm_start(vec![0.0; prob.dim()]),
    )
    .unwrap_err();
    assert!(e.0.contains("warm-start"), "{e}");
}

/// Oracle gradients for the new conjugates match central finite
/// differences of the oracle objective.
#[test]
fn new_regularizer_gradients_match_finite_differences() {
    let prob = random_problem(0x9E64, 3, 3, 13);
    let dim = prob.dim();
    let mut rng = Pcg64::new(23);
    let x: Vec<f64> = (0..dim).map(|_| rng.uniform(-0.4, 0.4)).collect();
    for kind in [RegKind::SquaredL2, RegKind::NegEntropy] {
        let reg = AnyRegularizer::build(kind, 0.7, 0.5, &prob.groups).unwrap();
        let mut oracle = DenseRegOracle::new(&prob, reg, ParallelCtx::new(1));
        let mut grad = vec![0.0; dim];
        oracle.eval(&x, &mut grad);
        let h = 1e-6;
        for i in 0..dim {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[i] += h;
            xm[i] -= h;
            let mut scratch = vec![0.0; dim];
            let fp = oracle.eval(&xp, &mut scratch);
            let fm = oracle.eval(&xm, &mut scratch);
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (fd - grad[i]).abs() <= 1e-5 * grad[i].abs().max(1.0),
                "{}: grad[{i}] = {} vs fd {}",
                kind.name(),
                grad[i],
                fd
            );
        }
    }
}

/// At ρ = 0 the group-lasso conjugate degenerates to the squared-ℓ2
/// conjugate (τ = 0, λ = γ), so both regularizers minimize the same
/// function — the optima must coincide (to solver tolerance; the
/// group-lasso kernel's √·² round trip keeps this from being bitwise).
#[test]
fn squared_l2_matches_group_lasso_at_rho_zero() {
    let prob = random_problem(0x9E65, 3, 3, 17);
    let tight = LbfgsOptions { max_iters: 3000, ftol: 1e-13, gtol: 1e-9, ..Default::default() };
    let gl = fastot::solve(
        &prob,
        &SolveOptions::new()
            .gamma(0.8)
            .rho(0.0)
            .regularizer(RegKind::GroupLasso)
            .lbfgs(tight.clone()),
    )
    .expect("group-lasso ρ=0");
    let l2 = fastot::solve(
        &prob,
        &SolveOptions::new()
            .gamma(0.8)
            .rho(0.0)
            .regularizer(RegKind::SquaredL2)
            .lbfgs(tight),
    )
    .expect("squared-l2");
    assert!(
        (gl.dual_objective - l2.dual_objective).abs() <= 1e-6,
        "gl={} l2={}",
        gl.dual_objective,
        l2.dual_objective
    );
    assert_eq!(l2.method, "fast+squared_l2");
}

/// Squared ℓ2 through the trait semi-dual reproduces the legacy
/// quadratic semi-dual byte for byte (same staging and water-filling
/// order), at 1 and 4 oracle threads.
#[test]
fn semidual_squared_l2_is_byte_identical_to_legacy() {
    let prob = random_problem(0x9E66, 3, 4, 23);
    let lbfgs = LbfgsOptions { max_iters: 200, ..Default::default() };
    let legacy = solve_semidual(&prob, 0.2, &lbfgs);
    for threads in [1usize, 4] {
        let traited = semidual::solve(
            &prob,
            &SolveOptions::new()
                .gamma(0.2)
                .regularizer(RegKind::SquaredL2)
                .threads(threads)
                .lbfgs(lbfgs.clone()),
        )
        .expect("semi-dual squared-l2");
        assert_eq!(legacy.alpha, traited.alpha, "threads={threads}: alpha bytes");
        assert_eq!(legacy.objective, traited.objective, "threads={threads}: objective");
        assert_eq!(legacy.iterations, traited.iterations, "threads={threads}: iterations");
        assert_eq!(legacy.plan, traited.plan, "threads={threads}: plan");
    }
}

/// The entropic semi-dual: its inner softmax satisfies the column
/// marginals by construction, the plan is nonnegative, and thread
/// counts don't change the bytes.
#[test]
fn semidual_negentropy_solves_and_hits_marginals() {
    let prob = random_problem(0x9E67, 3, 3, 19);
    let opts = SolveOptions::new()
        .gamma(0.5)
        .regularizer(RegKind::NegEntropy)
        .lbfgs(LbfgsOptions { max_iters: 300, ..Default::default() });
    let res = semidual::solve(&prob, &opts).expect("entropic semi-dual");
    assert!(res.objective.is_finite());
    for j in 0..prob.n() {
        let mut col = 0.0;
        for i in 0..prob.m() {
            let v = res.plan[(i, j)];
            assert!(v >= 0.0, "plan[{i},{j}] = {v}");
            col += v;
        }
        assert!(
            (col - prob.b[j]).abs() <= 1e-12 * prob.b[j].max(1.0),
            "column {j} mass {col} vs b {}",
            prob.b[j]
        );
    }
    let threaded = semidual::solve(&prob, &opts.clone().threads(4)).expect("threaded");
    assert_eq!(res.alpha, threaded.alpha, "semi-dual determinism across threads");
}

/// Full-dual and semi-dual solves of the same smoothed squared-ℓ2
/// problem agree at the optimum (strong duality of the relaxation).
#[test]
fn full_dual_and_semidual_squared_l2_agree() {
    let prob = random_problem(0x9E68, 2, 4, 13);
    let tight = LbfgsOptions { max_iters: 4000, ftol: 1e-13, gtol: 1e-9, ..Default::default() };
    let full = fastot::solve(
        &prob,
        &SolveOptions::new()
            .gamma(0.6)
            .rho(0.0)
            .regularizer(RegKind::SquaredL2)
            .lbfgs(tight.clone()),
    )
    .expect("full dual");
    let semi = semidual::solve(
        &prob,
        &SolveOptions::new().gamma(0.6).regularizer(RegKind::SquaredL2).lbfgs(tight),
    )
    .expect("semi-dual");
    assert!(
        (full.dual_objective - semi.objective).abs()
            <= 1e-6 * semi.objective.abs().max(1.0),
        "full={} semi={}",
        full.dual_objective,
        semi.objective
    );
}

/// The group lasso has no separable semi-dual: asking for one is a
/// structured error, not a panic.
#[test]
fn group_lasso_semidual_is_rejected() {
    let prob = random_problem(0x9E69, 2, 3, 11);
    let e = semidual::solve(
        &prob,
        &SolveOptions::new().gamma(0.5).rho(0.5).regularizer(RegKind::GroupLasso),
    )
    .unwrap_err();
    assert!(e.0.contains("semi-dual"), "{e}");
}

/// `GRPOT_REG` fills only the *unset* default: explicit selections and
/// the legacy pinned-group-lasso entry points are never re-routed. The
/// env var is process-global, so this is the only test that touches it,
/// and every other test in this binary pins its regularizer explicitly.
#[test]
fn env_default_fills_only_the_unset_option() {
    let prob = random_problem(0x9E6A, 2, 3, 11);
    let pinned = fastot::solve(&prob, &trait_opts(0.5, 0.5, 1, SimdMode::Auto)).unwrap();
    std::env::set_var("GRPOT_REG", "squared_l2");
    let unset = SolveOptions::new().gamma(0.5).rho(0.0);
    assert_eq!(unset.resolve_regularizer().unwrap(), RegKind::SquaredL2);
    let via_env = fastot::solve(
        &prob,
        &unset.lbfgs(LbfgsOptions { max_iters: 120, ..Default::default() }),
    )
    .unwrap();
    assert_eq!(via_env.method, "fast+squared_l2", "unset option follows the env");
    // Explicit selections and the legacy entry point ignore the env.
    let explicit = fastot::solve(&prob, &trait_opts(0.5, 0.5, 1, SimdMode::Auto)).unwrap();
    let legacy = solve_fast_ot(&prob, &legacy_cfg(0.5, 0.5, 1, SimdMode::Auto));
    std::env::remove_var("GRPOT_REG");
    assert_results_identical(&pinned, &explicit, "explicit selection under env");
    assert_results_identical(&legacy, &explicit, "legacy entry point under env");
    // A malformed value is a structured error at resolution time.
    std::env::set_var("GRPOT_REG", "lasso-soup");
    let e = SolveOptions::new().resolve_regularizer().unwrap_err();
    std::env::remove_var("GRPOT_REG");
    assert!(e.0.contains("unknown regularizer"), "{e}");
}
