//! Figure 6: number of gradient computations, origin vs ours, per
//! ρ ∈ {0.2, 0.4, 0.6, 0.8} on the MNIST→USPS task with γ = 0.1.
//!
//! Paper shape: ours computes a small fraction of origin's count
//! (down to 4.22%), shrinking as ρ grows (stronger group sparsity).

mod common;

use common::*;
use grpot::benchlib::{report_dir, Table};
use grpot::coordinator::config::Method;
use grpot::coordinator::sweep::run_job;
use grpot::data::digits;

fn main() {
    banner("fig6: gradient-computation counts per rho");
    let samples = size3(60, 400, 1000);
    let pair = digits::mnist_to_usps(samples, 0xF166);
    let prob = problem_of(&pair);
    let gamma = 0.1;

    let mut table = Table::new(
        "Fig. 6 — #gradient computations (MNIST→USPS, γ=0.1)",
        &["rho", "origin", "ours", "ours/origin %"],
    );
    let mut fractions = Vec::new();
    for &rho in &[0.2, 0.4, 0.6, 0.8] {
        let o = run_job(&prob, Method::Origin, gamma, rho, 10, max_iters());
        let f = run_job(&prob, Method::Fast, gamma, rho, 10, max_iters());
        assert_eq!(o.dual_objective, f.dual_objective, "Theorem 2");
        let frac = 100.0 * f.grads_computed as f64 / o.grads_computed.max(1) as f64;
        fractions.push((rho, frac));
        println!("rho={rho}: origin={} ours={} ({frac:.2}%)", o.grads_computed, f.grads_computed);
        table.row(vec![
            format!("{rho}"),
            format!("{}", o.grads_computed),
            format!("{}", f.grads_computed),
            format!("{frac:.2}"),
        ]);
    }
    table.emit(&report_dir(), "fig6_grad_counts");

    // Shape: the computed fraction shrinks as rho grows. Too noisy to
    // assert on the one-iteration smoke run.
    if !grpot::benchlib::smoke_mode() {
        assert!(
            fractions.last().unwrap().1 <= fractions.first().unwrap().1,
            "fraction should shrink with rho: {fractions:?}"
        );
    }
}
