"""L1 Pallas kernel: grouped soft-threshold transport-plan gradient.

One program instance handles one ``(group, column-tile)`` block of the
plan: it materializes the ``g × TJ`` tile of ``F = alpha ⊕ beta − C``,
reduces the positive part to the per-column group norm ``z``, applies
the soft threshold (Eq. 5 of the paper) and writes both the plan tile
and the ``z`` row.

TPU shaping notes (DESIGN.md §Hardware-Adaptation): the kernel is pure
VPU work (no matmul), so the design target is the HBM↔VMEM schedule.
The BlockSpec streams one ``g × TJ`` cost tile per step (the only O(mn)
operand); ``alpha``/``beta`` tiles are O(g + TJ) and stay resident.
With f32 and the default TJ ≤ 256, the live tile set is
``g·TJ·(2 copies) + g + TJ`` floats — a few hundred KB for g ≤ 256,
comfortably inside one core's ~16 MB VMEM, leaving headroom for
double-buffering the cost stream. ``interpret=True`` everywhere: the
CPU PJRT plugin cannot execute Mosaic custom-calls, and all numerics
are validated through this path (pytest vs ``ref.py``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(alpha_ref, beta_ref, tau_ref, lq_ref, c_ref, t_ref, z_ref):
    """One (group, column-tile) program.

    alpha_ref: (g,)     — this group's alpha slice
    beta_ref:  (tj,)    — this tile's beta slice
    tau_ref:   (1, 1)   — skip threshold  tau = gamma*rho
    lq_ref:    (1, 1)   — quadratic coeff lambda_quad = gamma*(1-rho)
    c_ref:     (g, tj)  — cost tile
    t_ref:     (g, tj)  — plan tile (output)
    z_ref:     (1, tj)  — group norm row (output)
    """
    f = alpha_ref[...][:, None] + beta_ref[...][None, :] - c_ref[...]
    fp = jnp.maximum(f, 0.0)
    z = jnp.sqrt(jnp.sum(fp * fp, axis=0, keepdims=True))  # (1, tj)
    tau = tau_ref[0, 0]
    lq = lq_ref[0, 0]
    safe_z = jnp.where(z > 0.0, z, 1.0)
    scale = jnp.where(z > tau, (z - tau) / (lq * safe_z), 0.0)
    t_ref[...] = fp * scale
    z_ref[...] = z


def _pick_tile(n: int, max_tile: int = 256) -> int:
    """Largest divisor of n not exceeding max_tile (keeps the grid exact
    without padding)."""
    best = 1
    for t in range(1, min(n, max_tile) + 1):
        if n % t == 0:
            best = t
    return best


@functools.partial(
    jax.jit, static_argnames=("num_groups", "group_size", "column_tile")
)
def grad_psi_pallas(
    alpha,
    beta,
    cost,
    tau,
    lambda_quad,
    *,
    num_groups: int,
    group_size: int,
    column_tile: int | None = None,
):
    """Pallas-kernel version of ``ref.grad_psi_uniform``.

    Returns ``(t, z)``: the plan (m × n) and the group norms (L × n).
    """
    m, n = cost.shape
    assert m == num_groups * group_size
    tj = column_tile or _pick_tile(n)
    assert n % tj == 0, f"column tile {tj} must divide n={n}"
    dtype = cost.dtype
    tau2 = jnp.asarray(tau, dtype=dtype).reshape(1, 1)
    lq2 = jnp.asarray(lambda_quad, dtype=dtype).reshape(1, 1)

    grid = (num_groups, n // tj)
    t, z = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((group_size,), lambda l, j: (l,)),
            pl.BlockSpec((tj,), lambda l, j: (j,)),
            pl.BlockSpec((1, 1), lambda l, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda l, j: (0, 0)),
            pl.BlockSpec((group_size, tj), lambda l, j: (l, j)),
        ],
        out_specs=[
            pl.BlockSpec((group_size, tj), lambda l, j: (l, j)),
            pl.BlockSpec((1, tj), lambda l, j: (l, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m, n), dtype),
            jax.ShapeDtypeStruct((num_groups, n), dtype),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(alpha, beta, tau2, lq2, cost)
    return t, z
