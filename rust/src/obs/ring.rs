//! Per-thread span rings: fixed-capacity, drop-oldest, lock-free on the
//! record path.
//!
//! Each recording thread owns one [`Ring`] (created on its first span
//! and registered once in a global list — the only lock, taken once per
//! thread lifetime, never per span). A ring slot is a seqlock over five
//! `AtomicU64` words: the writer bumps the sequence to odd, stores the
//! payload, then bumps to even; a drain snapshots slots read-only and
//! skips any slot whose sequence was odd or changed mid-read. Written
//! entirely in safe code — the crate's `unsafe` inventory (SIMD kernels
//! + the pool's type-erased job handoff) is unchanged.
//!
//! Overflow drops the *oldest* entries by construction: the writer
//! overwrites `head % capacity` and readers can observe at most the
//! last `capacity` spans per thread. Draining is non-destructive (a
//! read-only snapshot), so concurrent drains and in-flight writers
//! never coordinate.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Spans retained per thread (~5 words each). Enough for the tail of a
/// load run; older spans age out.
pub const DEFAULT_RING_CAPACITY: usize = 4096;

/// One recorded span, as drained from a ring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SpanEvent {
    /// Index into [`super::names::ALL`].
    pub name_id: u32,
    /// Small per-thread ordinal (Chrome's `tid`).
    pub tid: u32,
    /// Request trace ID (0 for spans outside any request).
    pub trace_id: u64,
    /// Start, nanoseconds since the process trace epoch.
    pub start_ns: u64,
    /// Duration in nanoseconds.
    pub dur_ns: u64,
}

/// Seqlock slot: `seq` odd while a write is in flight, even when the
/// payload words are consistent; 0 means never written.
#[derive(Default)]
struct Slot {
    seq: AtomicU64,
    name_tid: AtomicU64,
    trace: AtomicU64,
    start: AtomicU64,
    dur: AtomicU64,
}

/// Fixed-capacity drop-oldest span buffer for a single writer thread.
pub struct Ring {
    slots: Vec<Slot>,
    head: AtomicU64,
}

impl Ring {
    pub fn with_capacity(capacity: usize) -> Ring {
        let capacity = capacity.max(1);
        let mut slots = Vec::with_capacity(capacity);
        slots.resize_with(capacity, Slot::default);
        Ring { slots, head: AtomicU64::new(0) }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Spans ever recorded (monotonic; `min(recorded, capacity)` are
    /// still resident).
    pub fn recorded(&self) -> u64 {
        self.head.load(Ordering::Relaxed)
    }

    /// Record one span. Single-producer per ring (each thread writes
    /// only its own); drains may run concurrently and will skip this
    /// slot while the write is in flight.
    pub fn record(&self, name_id: u32, tid: u32, trace_id: u64, start_ns: u64, dur_ns: u64) {
        let h = self.head.fetch_add(1, Ordering::Relaxed);
        let slot = &self.slots[(h % self.slots.len() as u64) as usize];
        let seq = slot.seq.load(Ordering::Relaxed);
        slot.seq.store(seq | 1, Ordering::Release); // mark write in flight
        slot.name_tid
            .store(((name_id as u64) << 32) | tid as u64, Ordering::Release);
        slot.trace.store(trace_id, Ordering::Release);
        slot.start.store(start_ns, Ordering::Release);
        slot.dur.store(dur_ns, Ordering::Release);
        slot.seq.store((seq | 1).wrapping_add(1), Ordering::Release); // even again
    }

    /// Read-only snapshot of every stable slot. Slots that were never
    /// written, or whose writer was mid-store across every retry, are
    /// skipped — a drain never blocks a writer and never reads a torn
    /// span.
    pub fn snapshot(&self) -> Vec<SpanEvent> {
        let mut out = Vec::new();
        for slot in &self.slots {
            for _retry in 0..4 {
                let s1 = slot.seq.load(Ordering::Acquire);
                if s1 == 0 {
                    break; // never written
                }
                if s1 & 1 == 1 {
                    continue; // write in flight; retry
                }
                let name_tid = slot.name_tid.load(Ordering::Acquire);
                let trace = slot.trace.load(Ordering::Acquire);
                let start = slot.start.load(Ordering::Acquire);
                let dur = slot.dur.load(Ordering::Acquire);
                let s2 = slot.seq.load(Ordering::Acquire);
                if s1 != s2 {
                    continue; // overwritten mid-read; retry
                }
                out.push(SpanEvent {
                    name_id: (name_tid >> 32) as u32,
                    tid: name_tid as u32,
                    trace_id: trace,
                    start_ns: start,
                    dur_ns: dur,
                });
                break;
            }
        }
        out
    }
}

/// Global list of every thread's ring. Locked once per thread lifetime
/// (registration) and per drain — never on the span record path.
fn registry() -> &'static Mutex<Vec<Arc<Ring>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<Ring>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

/// Small per-thread ordinal for Chrome's `tid` field (OS thread IDs are
/// not portably numeric).
fn next_tid() -> u32 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed) as u32
}

thread_local! {
    static LOCAL: std::cell::OnceCell<(Arc<Ring>, u32)> = const { std::cell::OnceCell::new() };
}

/// Run `f` against the calling thread's ring (created and registered on
/// first use) and its trace `tid`.
pub fn with_local<T>(f: impl FnOnce(&Ring, u32) -> T) -> T {
    LOCAL.with(|cell| {
        let (ring, tid) = cell.get_or_init(|| {
            let ring = Arc::new(Ring::with_capacity(DEFAULT_RING_CAPACITY));
            registry()
                .lock()
                .unwrap_or_else(std::sync::PoisonError::into_inner)
                .push(Arc::clone(&ring));
            (ring, next_tid())
        });
        f(ring, *tid)
    })
}

/// Snapshot every registered ring (all threads, read-only).
pub fn snapshot_all() -> Vec<SpanEvent> {
    let rings: Vec<Arc<Ring>> = registry()
        .lock()
        .unwrap_or_else(std::sync::PoisonError::into_inner)
        .iter()
        .map(Arc::clone)
        .collect();
    let mut out = Vec::new();
    for ring in rings {
        out.extend(ring.snapshot());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overflow_keeps_newest() {
        let ring = Ring::with_capacity(4);
        for i in 0..10u64 {
            ring.record(0, 1, i, i * 100, 10);
        }
        assert_eq!(ring.recorded(), 10);
        let mut got: Vec<u64> = ring.snapshot().iter().map(|e| e.trace_id).collect();
        got.sort_unstable();
        // Capacity 4 → exactly the newest four survive, none torn.
        assert_eq!(got, vec![6, 7, 8, 9]);
    }

    #[test]
    fn snapshot_of_empty_ring_is_empty() {
        let ring = Ring::with_capacity(8);
        assert!(ring.snapshot().is_empty());
        assert_eq!(ring.recorded(), 0);
    }

    #[test]
    fn event_words_roundtrip() {
        let ring = Ring::with_capacity(2);
        ring.record(7, 42, 0xDEAD, 123, 456);
        let got = ring.snapshot();
        assert_eq!(
            got,
            vec![SpanEvent { name_id: 7, tid: 42, trace_id: 0xDEAD, start_ns: 123, dur_ns: 456 }]
        );
    }
}
