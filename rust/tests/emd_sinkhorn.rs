//! Cross-validation of the three OT solvers: exact network simplex,
//! entropic Sinkhorn and the regularized dual — they must agree in the
//! appropriate limits.

use grpot::linalg::Mat;
use grpot::ot::dual::OtProblem;
use grpot::ot::emd::emd;
use grpot::ot::fastot::{solve_fast_ot, FastOtConfig};
use grpot::ot::plan::recover_plan;
use grpot::ot::semidual::solve_semidual;
use grpot::ot::sinkhorn::sinkhorn_log;
use grpot::rng::Pcg64;
use grpot::solvers::lbfgs::LbfgsOptions;
use grpot::testing::{check, gen_simplex, Config};

#[test]
fn sinkhorn_approaches_emd_as_reg_vanishes() {
    check("sinkhorn → emd", &Config::cases(15), |rng| {
        let m = 2 + rng.below(5);
        let n = 2 + rng.below(5);
        let a = gen_simplex(rng, m);
        let b = gen_simplex(rng, n);
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
        let exact = emd(&a, &b, &cost);
        let coarse = sinkhorn_log(&a, &b, &cost, 0.1, 3000, 1e-10);
        let fine = sinkhorn_log(&a, &b, &cost, 0.005, 6000, 1e-10);
        // Entropic cost must upper-bound the LP and tighten with ε.
        if fine.transport_cost < exact.cost - 1e-6 {
            return Err(format!(
                "entropic beats exact LP: {} < {}",
                fine.transport_cost, exact.cost
            ));
        }
        if fine.transport_cost > coarse.transport_cost + 1e-6 {
            return Err(format!(
                "smaller ε should tighten: {} vs {}",
                fine.transport_cost, coarse.transport_cost
            ));
        }
        if (fine.transport_cost - exact.cost).abs() > 0.05 {
            return Err(format!(
                "ε=0.005 still far from LP: {} vs {}",
                fine.transport_cost, exact.cost
            ));
        }
        Ok(())
    });
}

#[test]
fn regularized_dual_cost_approaches_emd_for_small_gamma() {
    let mut rng = Pcg64::new(0xE3D);
    let m = 12;
    let n = 10;
    let a = vec![1.0 / m as f64; m];
    let b = vec![1.0 / n as f64; n];
    let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
    let labels: Vec<usize> = (0..m).map(|i| i / 4).collect();
    let prob = OtProblem::from_parts(a.clone(), b.clone(), &cost, &labels);
    let exact = emd(&a, &b, &cost);

    let cost_at = |gamma: f64| {
        let cfg = FastOtConfig {
            gamma,
            rho: 0.3,
            lbfgs: LbfgsOptions { max_iters: 3000, gtol: 1e-9, ftol: 1e-15, ..Default::default() },
            ..Default::default()
        };
        let res = solve_fast_ot(&prob, &cfg);
        recover_plan(&prob, &cfg.params(), &res.x).transport_cost(&prob)
    };
    let far = cost_at(1.0);
    let near = cost_at(1e-3);
    // Regularized plans under-ship mass at strong reg, so ⟨T,C⟩ may sit
    // below the LP cost; convergence in γ is what we check.
    assert!(
        (near - exact.cost).abs() < (far - exact.cost).abs() + 1e-9,
        "γ → 0 must approach the LP cost: far={far} near={near} exact={}",
        exact.cost
    );
    assert!((near - exact.cost).abs() < 0.02, "near={near} vs exact={}", exact.cost);
}

#[test]
fn semidual_consistent_with_full_dual_quadratic_case() {
    let mut rng = Pcg64::new(0x5D);
    let m = 9;
    let n = 7;
    let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
    let labels: Vec<usize> = (0..m).map(|i| i / 3).collect();
    let prob = OtProblem::from_parts(
        vec![1.0 / m as f64; m],
        vec![1.0 / n as f64; n],
        &cost,
        &labels,
    );
    let gamma = 0.05;
    // Full dual with ρ=0 (pure quadratic).
    let cfg = FastOtConfig {
        gamma,
        rho: 0.0,
        lbfgs: LbfgsOptions { max_iters: 3000, gtol: 1e-9, ftol: 1e-15, ..Default::default() },
        ..Default::default()
    };
    let full = solve_fast_ot(&prob, &cfg);
    let full_plan = recover_plan(&prob, &cfg.params(), &full.x);
    // Semi-dual (exact column marginals).
    let semi =
        solve_semidual(&prob, gamma, &LbfgsOptions { max_iters: 3000, ..Default::default() });
    // Transport costs agree to the smoothing scale.
    let c_full = full_plan.transport_cost(&prob);
    let c_semi = {
        let mut s = 0.0;
        for j in 0..prob.n() {
            let c_j = prob.cost_t().row(j);
            for i in 0..prob.m() {
                s += semi.plan[(i, j)] * c_j[i];
            }
        }
        s
    };
    assert!(
        (c_full - c_semi).abs() < 0.02,
        "full-dual vs semi-dual transport cost: {c_full} vs {c_semi}"
    );
}

#[test]
fn emd_random_instances_have_valid_certificates() {
    check("emd optimality certificates", &Config::cases(30), |rng| {
        let m = 2 + rng.below(7);
        let n = 2 + rng.below(7);
        let a = gen_simplex(rng, m);
        let b = gen_simplex(rng, n);
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 2.0));
        let r = emd(&a, &b, &cost);
        // Primal feasibility.
        let rs = r.plan.row_sums();
        for (i, (&got, &want)) in rs.iter().zip(&a).enumerate() {
            if (got - want).abs() > 1e-7 {
                return Err(format!("row {i} marginal {got} vs {want}"));
            }
        }
        // Dual feasibility + complementary slackness.
        for i in 0..m {
            for j in 0..n {
                let red = cost[(i, j)] - r.u[i] - r.v[j];
                if red < -1e-7 {
                    return Err(format!("dual infeasible at ({i},{j}): {red}"));
                }
                if r.plan[(i, j)] > 1e-8 && red.abs() > 1e-7 {
                    return Err(format!("slackness violated at ({i},{j})"));
                }
            }
        }
        // Strong duality.
        let dual: f64 = r.u.iter().zip(&a).map(|(&x, &y)| x * y).sum::<f64>()
            + r.v.iter().zip(&b).map(|(&x, &y)| x * y).sum::<f64>();
        if (dual - r.cost).abs() > 1e-6 {
            return Err(format!("duality gap {} vs {}", dual, r.cost));
        }
        Ok(())
    });
}
