//! Build-surface smoke test: the `prelude` quickstart from `lib.rs`,
//! end to end. This is the one test that must stay green for tier-1 to
//! mean anything — it exercises dataset generation, problem assembly,
//! both solvers (screened and dense), Theorem-2 equality and plan
//! recovery without any heavier suite in the way.

use grpot::ot::plan::recover_plan;
use grpot::prelude::*;

#[test]
fn prelude_quickstart_runs_and_matches() {
    // Two tiny class-clustered domains (the lib.rs doc example).
    let ds = grpot::data::synthetic::controlled_classes(4, 5, 0xC0FFEE);
    let prob = OtProblem::from_dataset(&ds);
    assert_eq!(prob.m(), 20);
    assert_eq!(prob.n(), 20);
    assert_eq!(prob.groups.num_groups(), 4);

    let cfg = FastOtConfig { gamma: 1.0, rho: 0.5, ..Default::default() };
    let fast = solve_fast_ot(&prob, &cfg);
    let origin = solve_origin(&prob, &cfg);

    // Theorem 2: the screened solver reproduces the dense baseline.
    assert!(
        (fast.dual_objective - origin.dual_objective).abs() < 1e-9,
        "fast={} origin={}",
        fast.dual_objective,
        origin.dual_objective
    );
    assert_eq!(fast.x, origin.x, "identical trajectories, not just objectives");
    assert!(fast.dual_objective.is_finite());
    assert!(fast.iterations > 0);

    // The plan is recoverable and feasible-ish at this γ.
    let plan = recover_plan(&prob, &cfg.params(), &fast.x);
    assert!(plan.t.as_slice().iter().all(|&v| v >= 0.0 && v.is_finite()));
    let (va, vb) = plan.marginal_violation(&prob);
    assert!(va < 0.5 && vb < 0.5, "marginal violation ({va}, {vb})");
}

#[test]
fn prelude_exports_are_usable() {
    // Every prelude export referenced so the re-export list cannot rot.
    let _mat: Mat = Mat::zeros(2, 2);
    let mut rng = Pcg64::new(7);
    assert!((0.0..1.0).contains(&rng.f64()));
    let gs = GroupStructure::uniform(2, 3);
    assert_eq!(gs.num_samples(), 6);
    let params = DualParams::new(1.0, 0.5);
    assert!((params.tau() - 0.5).abs() < 1e-15);
    let opts = LbfgsOptions::default();
    assert_eq!(opts.memory, 10);
    let cm = {
        let pair = grpot::data::synthetic::controlled(2, 2, 1);
        CostMatrix::squared_euclidean(&pair)
    };
    assert_eq!(cm.c.shape(), (4, 4));
}
