//! Batched solve-many-at-once driver — K independent (γ, ρ,
//! warm-start) problems over **one** [`OtProblem`], solved in lockstep
//! through one fused oracle pass per evaluation round (ISSUE 10's
//! tentpole).
//!
//! Each lane owns a full solver: its own deferred L-BFGS pump
//! ([`crate::solvers::lbfgs::Lbfgs::deferred`]), screening snapshots,
//! working set and counters. What is fused is only the oracle
//! evaluation ([`crate::ot::screening::BatchedOracle::eval_many`]): the
//! K lanes' pending trial points are evaluated in a single pass over
//! the cost columns, reading each surviving cost segment once — the
//! SIMD lanes carry the *same column under K different problems*
//! instead of four columns of one problem. Stragglers retire as they
//! converge; the remaining lanes keep batching.
//!
//! **Hard contract**: every lane's result — `x`, objective,
//! iterations, stop reason and every [`OracleStats`] counter except
//! `tiles_built` (staging is shared, so the factored backend
//! synthesizes each segment once per K-group) — is byte-identical to
//! its sequential [`crate::ot::fastot::solve`] at any K, thread count
//! and SIMD backend. `tests/batch_equivalence.rs` pins this across the
//! full matrix.

use super::dual::{DualOracle, OracleStats, OtProblem};
use super::fastot::{self, full_dual_x0, FastOtConfig, FastOtResult};
use super::regularizer::RegKind;
use super::screening::{BatchLaneSpec, BatchedOracle};
use super::solve::SolveOptions;
use crate::error::Result;
use crate::obs::report::skipped_fraction;
use crate::obs::{names, RoundTelemetry, Span};
use crate::simd::LANES;
use crate::solvers::lbfgs::{Lbfgs, LbfgsStatus};
use crate::solvers::StopReason;
use std::time::Instant;

/// Solve every entry of `opts` against `prob`, batching group-lasso
/// entries in lockstep groups of up to [`LANES`]; entries with other
/// regularizers (no screening oracle, hence nothing to fuse) fall back
/// to the sequential [`fastot::solve`]. Results come back in input
/// order, each byte-identical to its sequential solve.
pub fn solve_batched(prob: &OtProblem, opts: &[SolveOptions]) -> Result<Vec<FastOtResult>> {
    let mut results: Vec<Option<FastOtResult>> = (0..opts.len()).map(|_| None).collect();
    let mut lasso: Vec<usize> = Vec::new();
    for (i, opt) in opts.iter().enumerate() {
        match opt.resolve_regularizer()? {
            RegKind::GroupLasso => lasso.push(i),
            _ => results[i] = Some(fastot::solve(prob, opt)?),
        }
    }
    for group in lasso.chunks(LANES) {
        solve_lane_group(prob, opts, group, &mut results)?;
    }
    Ok(results.into_iter().map(|r| r.expect("every entry solved")).collect())
}

/// The per-round counter tuple the round telemetry diffs (same fields
/// as the sequential driver's closure).
fn counters(s: &OracleStats) -> (u64, u64, u64, u64) {
    (s.grads_computed, s.grads_skipped, s.ub_checks, s.ws_hits)
}

/// Everything one lane carries besides its pump: config, telemetry
/// accumulators and the open solve span.
struct LaneState {
    /// Index into the caller's `opts`/results.
    idx: usize,
    cfg: FastOtConfig,
    start: Instant,
    solve_span: Option<Span>,
    iter_in_block: usize,
    outer_rounds: usize,
    observing: bool,
    prev: (u64, u64, u64, u64),
    rounds: Vec<RoundTelemetry>,
    pool_at_start: Option<crate::obs::PoolUtilization>,
}

impl LaneState {
    fn round_delta(&mut self, oracle: &dyn DualOracle) {
        let cur = counters(oracle.stats());
        self.rounds.push(RoundTelemetry {
            round: self.rounds.len() as u32 + 1,
            grads_computed: cur.0 - self.prev.0,
            grads_skipped: cur.1 - self.prev.1,
            ub_checks: cur.2 - self.prev.2,
            ws_hits: cur.3 - self.prev.3,
            ws_density: oracle.working_set_density(),
        });
        self.prev = cur;
    }
}

/// The sequential driver's between-iterations checkpoint, in pump form:
/// refresh after each full block of `r` iterations, then the
/// cancellation poll, the fault-injection checkpoint, and the solver's
/// own stop checks (`advance`). Returns `Some(reason)` when the lane is
/// done, `None` when it has a pending evaluation for the next fused
/// pass. The order matches [`fastot::drive_from`] exactly, so a lane
/// stops at the same point — with the same iteration count — as its
/// sequential solve.
fn lane_boundary(
    p: usize,
    batch: &mut BatchedOracle<'_>,
    pump: &mut Lbfgs,
    st: &mut LaneState,
) -> Option<StopReason> {
    if st.iter_in_block == st.cfg.r {
        let _round_span = Span::start_full(names::OUTER_ROUND, st.cfg.trace_id);
        batch.lane_mut(p).refresh(pump.x());
        st.outer_rounds += 1;
        if st.observing {
            st.round_delta(batch.lane(p));
        }
        st.iter_in_block = 0;
    }
    if st.cfg.cancel.as_ref().is_some_and(|t| t.is_cancelled()) {
        return Some(StopReason::Cancelled);
    }
    // Same escalation as the sequential driver: the batched driver has
    // no per-lane error channel, so an `err` failpoint panics and the
    // serving engine's unwind guard structures the failure.
    if let Err(e) = crate::fault::check(crate::fault::sites::ORACLE_EVAL) {
        panic!("{e}");
    }
    match pump.advance() {
        LbfgsStatus::NeedEval => None,
        LbfgsStatus::Stopped(r) => Some(r),
        LbfgsStatus::Seeded | LbfgsStatus::Iterated => {
            unreachable!("advance never yields Seeded/Iterated")
        }
    }
}

/// Solve one lockstep group of ≤ [`LANES`] group-lasso entries.
fn solve_lane_group(
    prob: &OtProblem,
    opts_all: &[SolveOptions],
    idxs: &[usize],
    results: &mut [Option<FastOtResult>],
) -> Result<()> {
    let k = idxs.len();
    // One shared context for the group: the fused pass parallelizes
    // over column chunks exactly like a sequential solve, so the first
    // entry's ctx/threads choice governs (entries coalesced into one
    // batch are expected to agree — the serving engine and sweep both
    // pass one engine-wide ctx).
    let ctx = opts_all[idxs[0]].make_ctx();
    let mut specs = Vec::with_capacity(k);
    let mut cfgs: Vec<FastOtConfig> = Vec::with_capacity(k);
    let mut x0s: Vec<Vec<f64>> = Vec::with_capacity(k);
    for &i in idxs {
        let opt = &opts_all[i];
        let cfg = opt.fastot_config();
        assert!(cfg.r >= 1, "snapshot interval must be >= 1");
        specs.push(BatchLaneSpec {
            params: cfg.params(),
            use_working_set: cfg.use_working_set,
            simd: cfg.simd,
            cancel: cfg.cancel.clone(),
            ring_budget_bytes: opt.resolve_tile_ring_bytes()?,
        });
        x0s.push(full_dual_x0(prob, opt)?);
        cfgs.push(cfg);
    }
    let mut batch = BatchedOracle::new(prob, &specs, ctx);

    let mut states: Vec<LaneState> = Vec::with_capacity(k);
    let mut pumps: Vec<Lbfgs> = Vec::with_capacity(k);
    let mut live = vec![true; k];
    for (p, cfg) in cfgs.into_iter().enumerate() {
        let observing = cfg.observer.is_some();
        let pool_at_start = observing.then(|| batch.ctx().pool_stats());
        let solve_span = Some(Span::start_full(names::SOLVE, cfg.trace_id));
        // Warm starts refresh the lane's snapshots at x0 before the
        // seed evaluation, exactly like the sequential driver.
        if x0s[p].iter().any(|&v| v != 0.0) {
            batch.lane_mut(p).refresh(&x0s[p]);
        }
        let prev = counters(batch.lane(p).stats());
        let mut pump = Lbfgs::deferred(x0s[p].clone(), cfg.lbfgs.clone());
        // A deferred pump's first advance always requests the seed
        // evaluation (no checks precede it — the sequential driver's
        // seed eval inside `Lbfgs::new` precedes its first checkpoint
        // too).
        let _seed_status = pump.advance();
        debug_assert_eq!(_seed_status, LbfgsStatus::NeedEval);
        states.push(LaneState {
            idx: idxs[p],
            cfg,
            start: Instant::now(),
            solve_span,
            iter_in_block: 0,
            outer_rounds: 0,
            observing,
            prev,
            rounds: Vec::new(),
            pool_at_start,
        });
        pumps.push(pump);
    }

    let mut fs = vec![0.0; k];
    let mut grads: Vec<Vec<f64>> = (0..k).map(|_| vec![0.0; prob.dim()]).collect();
    while live.iter().any(|&b| b) {
        // One fused pass evaluates every live lane's pending trial.
        let xs: Vec<&[f64]> = pumps.iter().map(|s| s.pending()).collect();
        batch.eval_many(&xs, &live, &mut fs, &mut grads);
        for p in 0..k {
            if !live[p] {
                continue;
            }
            let stop = match pumps[p].supply(fs[p], &grads[p]) {
                // Mid-line-search: the lane's next trial is pending for
                // the next fused pass, no checkpoint in between (the
                // sequential pump has none there either).
                LbfgsStatus::NeedEval => None,
                LbfgsStatus::Seeded => lane_boundary(p, &mut batch, &mut pumps[p], &mut states[p]),
                LbfgsStatus::Iterated => {
                    states[p].iter_in_block += 1;
                    lane_boundary(p, &mut batch, &mut pumps[p], &mut states[p])
                }
                LbfgsStatus::Stopped(r) => Some(r),
            };
            if let Some(reason) = stop {
                finalize_lane(p, reason, &batch, &pumps[p], &mut states[p], results);
                live[p] = false;
            }
        }
    }
    Ok(())
}

/// Assemble a retired lane's [`FastOtResult`] and [`SolveReport`] —
/// the sequential driver's tail, per lane. The pump is read, not
/// consumed, so the lockstep loop's `pending()` view over all lanes
/// stays valid.
///
/// [`SolveReport`]: crate::obs::SolveReport
fn finalize_lane(
    p: usize,
    stop: StopReason,
    batch: &BatchedOracle<'_>,
    pump: &Lbfgs,
    st: &mut LaneState,
    results: &mut [Option<FastOtResult>],
) {
    let iterations = pump.iterations();
    let x = pump.x().to_vec();
    let f = pump.f();
    let stats = batch.lane(p).stats().clone();
    let wall_time_s = st.start.elapsed().as_secs_f64();
    let method = if st.cfg.use_working_set { "fast" } else { "fast-nows" };
    if let Some(hook) = &st.cfg.observer {
        if counters(&stats) != st.prev {
            st.round_delta(batch.lane(p));
        }
        let report = crate::obs::SolveReport {
            method: method.to_string(),
            trace_id: st.cfg.trace_id,
            stop: stop.name(),
            iterations,
            outer_rounds: st.outer_rounds,
            evals: stats.evals,
            line_search_evals: stats.evals.saturating_sub(iterations as u64 + 1),
            grads_computed: stats.grads_computed,
            grads_skipped: stats.grads_skipped,
            ub_checks: stats.ub_checks,
            ws_hits: stats.ws_hits,
            tiles_built: stats.tiles_built,
            skipped_group_fraction: skipped_fraction(stats.grads_computed, stats.grads_skipped),
            simd_backend: batch.lane(p).dispatch().name(),
            rounds: std::mem::take(&mut st.rounds),
            pool: match &st.pool_at_start {
                Some(at_start) => batch.ctx().pool_stats().since(at_start),
                None => crate::obs::PoolUtilization::default(),
            },
            wall_time_s,
        };
        hook.emit(&report);
    }
    st.solve_span.take();
    results[st.idx] = Some(FastOtResult {
        x,
        dual_objective: -f,
        iterations,
        outer_rounds: st.outer_rounds,
        stop,
        stats,
        wall_time_s,
        method: method.to_string(),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    fn random_problem(seed: u64, l: usize, g: usize, n: usize) -> OtProblem {
        let mut rng = Pcg64::new(seed);
        let m = l * g;
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
        let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
        OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
    }

    fn assert_result_eq(batched: &FastOtResult, seq: &FastOtResult, what: &str) {
        assert_eq!(batched.x, seq.x, "x {what}");
        assert_eq!(batched.dual_objective, seq.dual_objective, "objective {what}");
        assert_eq!(batched.iterations, seq.iterations, "iterations {what}");
        assert_eq!(batched.outer_rounds, seq.outer_rounds, "outer_rounds {what}");
        assert_eq!(batched.stop, seq.stop, "stop {what}");
        assert_eq!(batched.method, seq.method, "method {what}");
        let (a, b) = (&batched.stats, &seq.stats);
        assert_eq!(a.evals, b.evals, "evals {what}");
        assert_eq!(a.grads_computed, b.grads_computed, "grads_computed {what}");
        assert_eq!(a.grads_skipped, b.grads_skipped, "grads_skipped {what}");
        assert_eq!(a.ub_checks, b.ub_checks, "ub_checks {what}");
        assert_eq!(a.ws_hits, b.ws_hits, "ws_hits {what}");
        assert_eq!(a.per_eval_grads, b.per_eval_grads, "per_eval_grads {what}");
    }

    /// The module-level smoke of the hard contract (the full
    /// K × dispatch × threads × backend matrix lives in
    /// `tests/batch_equivalence.rs`): a heterogeneous 4-lane batch must
    /// reproduce each sequential solve byte-for-byte.
    #[test]
    fn batched_group_matches_sequential_solves() {
        let prob = random_problem(21, 4, 3, 9);
        let gammas_rhos = [(0.5, 0.6), (1.5, 0.3), (0.2, 0.8), (5.0, 0.7)];
        let opts: Vec<SolveOptions> = gammas_rhos
            .iter()
            .map(|&(gamma, rho)| {
                SolveOptions::new().gamma(gamma).rho(rho).max_iters(60).regularizer(RegKind::GroupLasso)
            })
            .collect();
        let batched = solve_batched(&prob, &opts).unwrap();
        assert_eq!(batched.len(), opts.len());
        for (i, opt) in opts.iter().enumerate() {
            let seq = fastot::solve(&prob, opt).unwrap();
            assert_result_eq(&batched[i], &seq, &format!("lane {i}"));
        }
    }

    /// Non-group-lasso entries interleave with batched lanes and fall
    /// back to the sequential solver, with input order preserved.
    #[test]
    fn mixed_regularizers_fall_back_per_entry() {
        let prob = random_problem(9, 3, 3, 7);
        let opts = vec![
            SolveOptions::new().gamma(0.5).rho(0.5).max_iters(40).regularizer(RegKind::GroupLasso),
            SolveOptions::new().gamma(0.5).max_iters(40).regularizer(RegKind::SquaredL2),
            SolveOptions::new().gamma(1.2).rho(0.4).max_iters(40).regularizer(RegKind::GroupLasso),
        ];
        let batched = solve_batched(&prob, &opts).unwrap();
        for (i, opt) in opts.iter().enumerate() {
            let seq = fastot::solve(&prob, opt).unwrap();
            assert_eq!(batched[i].x, seq.x, "entry {i}");
            assert_eq!(batched[i].method, seq.method, "entry {i}");
        }
        assert_eq!(batched[1].method, "fast+squared_l2");
    }

    /// More entries than LANES: the driver forms consecutive lockstep
    /// groups and every one still matches its sequential solve.
    #[test]
    fn groups_of_more_than_lanes_chunk_correctly() {
        let prob = random_problem(33, 3, 4, 8);
        let opts: Vec<SolveOptions> = (0..LANES + 3)
            .map(|i| {
                SolveOptions::new()
                    .gamma(0.3 + 0.2 * i as f64)
                    .rho(0.1 + 0.1 * (i % 5) as f64)
                    .max_iters(40)
                    .regularizer(RegKind::GroupLasso)
            })
            .collect();
        let batched = solve_batched(&prob, &opts).unwrap();
        for (i, opt) in opts.iter().enumerate() {
            let seq = fastot::solve(&prob, opt).unwrap();
            assert_result_eq(&batched[i], &seq, &format!("entry {i}"));
        }
    }

    #[test]
    fn empty_batch_is_empty() {
        let prob = random_problem(5, 3, 3, 5);
        assert!(solve_batched(&prob, &[]).unwrap().is_empty());
    }

    /// A pre-cancelled lane retires at its first checkpoint with zero
    /// iterations — without disturbing its batchmates.
    #[test]
    fn cancelled_lane_retires_without_disturbing_others() {
        let prob = random_problem(5, 3, 3, 6);
        let token = crate::fault::CancelToken::new();
        token.cancel();
        let opts = vec![
            SolveOptions::new().gamma(0.5).rho(0.5).max_iters(40).regularizer(RegKind::GroupLasso),
            SolveOptions::new()
                .gamma(0.5)
                .rho(0.5)
                .max_iters(40)
                .regularizer(RegKind::GroupLasso)
                .cancel(token),
        ];
        let batched = solve_batched(&prob, &opts).unwrap();
        assert_eq!(batched[1].stop, StopReason::Cancelled);
        assert_eq!(batched[1].iterations, 0);
        let seq = fastot::solve(&prob, &opts[0]).unwrap();
        assert_result_eq(&batched[0], &seq, "uncancelled lane");
    }
}
