//! Cost-matrix backends: dense (resident `n×m` transposed matrix) and
//! factored (coordinates + squared norms, cost synthesized on demand).
//!
//! The dense path stores every `c_ij` twice on the vector hot path (the
//! transposed matrix plus the SIMD tile pack), which caps problem size
//! at memory long before compute. The factored backend instead keeps
//! only the point coordinates and their squared norms — O((m+n)·d)
//! instead of O(m·n) — and synthesizes squared-ℓ2 cost values lazily
//! via the expansion
//!
//! ```text
//! ‖x − y‖² = ‖x‖² + ‖y‖² − 2⟨x, y⟩
//! ```
//!
//! (the same identity [`crate::linalg::sq_euclidean_cost`] expands, as
//! in fugw's `_low_rank_squared_l2`). Synthesis replays the dense
//! construction pipeline operation-for-operation — same `dot`, same
//! clamp at 0, same multiply by the precomputed `1/max` — so a
//! synthesized value is **bitwise equal** to the corresponding entry of
//! the dense matrix, and every solver path stays byte-identical across
//! backends (`tests/cost_equivalence.rs`).
//!
//! On the vector path, synthesized (panel × group) tiles are cached in
//! a small per-chunk [`TileRing`] in the exact `[i][lane]` layout of
//! [`crate::ot::pack::PackedCost`], so the quad kernels consume a tile
//! stream instead of a resident pack. Screened-out groups never enter
//! the ring at all — screening skips the *cost computation*, not just
//! the gradient, a multiplicative win the dense layout cannot get.

use crate::err;
use crate::error::Result;
use crate::linalg::{self, Mat};
use crate::simd::LANES;
use std::collections::HashMap;
use std::ops::Range;

/// Which cost backend a problem build uses — the wire/CLI/config-level
/// selector (`--cost`, the serve request's `"cost"` field, `GRPOT_COST`).
/// Parsing mirrors [`crate::ot::regularizer::RegKind`]: unknown names
/// are a structured error, never a panic.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CostMode {
    /// Defer to `GRPOT_COST` when set (a bad value is a structured
    /// error), else [`CostMode::Dense`]. An explicit selection always
    /// wins over the environment.
    #[default]
    Auto,
    /// Materialize the full transposed cost matrix (the historical
    /// behavior, byte-for-byte).
    Dense,
    /// Store coordinates + squared norms only; synthesize cost tiles on
    /// demand. Requires point coordinates (squared-ℓ2 costs), so
    /// explicit-cost constructors ([`crate::ot::dual::OtProblem::from_parts`])
    /// always build dense.
    Factored,
}

impl CostMode {
    pub fn name(&self) -> &'static str {
        match self {
            CostMode::Auto => "auto",
            CostMode::Dense => "dense",
            CostMode::Factored => "factored",
        }
    }

    pub fn parse(s: &str) -> Result<CostMode> {
        match s {
            "auto" => Ok(CostMode::Auto),
            "dense" => Ok(CostMode::Dense),
            "factored" | "lowrank" | "low-rank" => Ok(CostMode::Factored),
            other => Err(err!("unknown cost mode '{other}' (expected auto|dense|factored)")),
        }
    }

    /// The concrete backend this mode selects: `Dense`/`Factored` pass
    /// through; `Auto` consults `GRPOT_COST` (bad value = structured
    /// error) and falls back to `Dense` when unset.
    pub fn resolve(self) -> Result<CostMode> {
        match self {
            CostMode::Auto => match std::env::var("GRPOT_COST") {
                Ok(s) => match CostMode::parse(&s)? {
                    CostMode::Auto => Ok(CostMode::Dense),
                    explicit => Ok(explicit),
                },
                Err(_) => Ok(CostMode::Dense),
            },
            explicit => Ok(explicit),
        }
    }

    /// The environment-resolved default — what an unset selection uses.
    /// The CLI validates this at launch (exit 2 on a malformed
    /// `GRPOT_COST`) so background solves never trip over it mid-flight.
    pub fn env_default() -> Result<CostMode> {
        CostMode::Auto.resolve()
    }
}

/// The factored squared-ℓ2 cost: grouped-order source coordinates,
/// target coordinates, their squared norms, and the reciprocal of the
/// dense pipeline's max-normalization constant. Total footprint
/// O((m+n)·d) — independent of m·n.
pub struct FactoredCost {
    /// Source coordinates (`m×d`), rows already permuted into the
    /// problem's sorted/grouped order.
    xs: Mat,
    /// Target coordinates (`n×d`).
    xt: Mat,
    /// `‖xs_i‖²` per source row (same 4-lane [`linalg::nrm2_sq`]
    /// accumulation the dense pipeline uses).
    xs_sq: Vec<f64>,
    /// `‖xt_j‖²` per target row.
    xt_sq: Vec<f64>,
    /// `1 / max_ij c_ij` (1.0 when the max is 0) — the exact factor
    /// [`linalg::normalize_by_max`] would have multiplied by.
    inv_max: f64,
}

impl FactoredCost {
    /// Build from grouped-order source rows and target rows. One
    /// streaming O(m·n·d) pass finds the same max entry the dense
    /// pipeline normalizes by (entries are already clamped ≥ 0, so the
    /// running max equals `Mat::max_abs` of the materialized matrix) —
    /// compute-heavy but memory-flat, and amortized over a whole solve.
    pub(crate) fn build(xs: Mat, xt: Mat) -> FactoredCost {
        assert_eq!(xs.cols(), xt.cols(), "feature dims differ");
        let xs_sq: Vec<f64> = (0..xs.rows()).map(|i| linalg::nrm2_sq(xs.row(i))).collect();
        let xt_sq: Vec<f64> = (0..xt.rows()).map(|j| linalg::nrm2_sq(xt.row(j))).collect();
        let mut max = 0.0f64;
        for i in 0..xs.rows() {
            let xi = xs.row(i);
            for j in 0..xt.rows() {
                let v = (xs_sq[i] + xt_sq[j] - 2.0 * linalg::dot(xi, xt.row(j))).max(0.0);
                if v > max {
                    max = v;
                }
            }
        }
        let inv_max = if max > 0.0 { 1.0 / max } else { 1.0 };
        FactoredCost { xs, xt, xs_sq, xt_sq, inv_max }
    }

    /// Number of source points (rows of the implicit cost).
    #[inline]
    pub fn m(&self) -> usize {
        self.xs.rows()
    }

    /// Number of target points (columns of the implicit cost).
    #[inline]
    pub fn n(&self) -> usize {
        self.xt.rows()
    }

    /// Feature dimension.
    #[inline]
    pub fn dim(&self) -> usize {
        self.xs.cols()
    }

    /// One synthesized entry `c_ij` — bitwise equal to the dense
    /// pipeline's `normalize_by_max(sq_euclidean_cost(xs, xt))[(i, j)]`:
    /// same expansion, same clamp, then the same single multiply by the
    /// stored reciprocal.
    #[inline]
    pub fn entry(&self, i: usize, j: usize) -> f64 {
        let v = self.xs_sq[i] + self.xt_sq[j] - 2.0 * linalg::dot(self.xs.row(i), self.xt.row(j));
        v.max(0.0) * self.inv_max
    }

    /// Synthesize the full cost column `j` (`buf[i] = c_ij`, length m).
    pub fn fill_col(&self, j: usize, buf: &mut [f64]) {
        debug_assert_eq!(buf.len(), self.m());
        for (i, out) in buf.iter_mut().enumerate() {
            *out = self.entry(i, j);
        }
    }

    /// Synthesize one group segment of column `j`:
    /// `buf[k] = c_{(rows.start + k), j}`.
    #[inline]
    pub fn fill_seg(&self, j: usize, rows: Range<usize>, buf: &mut [f64]) {
        debug_assert_eq!(buf.len(), rows.len());
        for (k, i) in rows.enumerate() {
            buf[k] = self.entry(i, j);
        }
    }

    /// Synthesize all full quads of one (panel, group) tile in the
    /// packed `[i][lane]` layout of [`crate::ot::pack::PackedCost`]:
    /// `buf[q·LANES·g + k·LANES + t] = c_{(rows.start + k), (j0 + q·LANES + t)}`
    /// — quad `q`'s slice is `buf[q·LANES·g .. (q+1)·LANES·g]`, exactly
    /// what [`crate::simd::group_quad_contrib`] consumes.
    pub fn fill_panel_group(&self, j0: usize, quads: usize, rows: Range<usize>, buf: &mut [f64]) {
        let g = rows.len();
        debug_assert_eq!(buf.len(), quads * LANES * g);
        for q in 0..quads {
            let base = q * LANES * g;
            for (k, i) in rows.clone().enumerate() {
                for t in 0..LANES {
                    buf[base + k * LANES + t] = self.entry(i, j0 + q * LANES + t);
                }
            }
        }
    }

    /// Whether every synthesizable entry is finite: with finite
    /// coordinates each entry is `(xs_sq[i] + xt_sq[j] − 2·dot)·inv_max`
    /// clamped at 0, finite iff the norms and `inv_max` are — an O(m+n)
    /// audit, no m×n scan.
    pub(crate) fn is_finite(&self) -> bool {
        self.inv_max.is_finite()
            && self.xs_sq.iter().all(|v| v.is_finite())
            && self.xt_sq.iter().all(|v| v.is_finite())
    }

    /// Resident bytes of the factored representation.
    pub fn bytes(&self) -> usize {
        std::mem::size_of::<f64>()
            * (self.xs.rows() * self.xs.cols()
                + self.xt.rows() * self.xt.cols()
                + self.xs_sq.len()
                + self.xt_sq.len())
    }
}

/// The cost backend an [`crate::ot::dual::OtProblem`] carries. `Dense`
/// holds the transposed (`n×m`) matrix the oracles historically walked;
/// `Factored` holds coordinates only and synthesizes on demand.
pub enum CostMatrix {
    Dense(Mat),
    Factored(FactoredCost),
}

impl CostMatrix {
    #[inline]
    pub fn is_factored(&self) -> bool {
        matches!(self, CostMatrix::Factored(_))
    }

    /// Backend name for telemetry / `grpot info`.
    pub fn mode_name(&self) -> &'static str {
        match self {
            CostMatrix::Dense(_) => "dense",
            CostMatrix::Factored(_) => "factored",
        }
    }

    /// Cost column `j` as a slice: zero-copy for dense (row `j` of the
    /// transposed matrix), synthesized into `buf` for factored. `buf`
    /// is resized to m on demand and untouched on the dense path.
    pub fn col<'a>(&'a self, j: usize, buf: &'a mut Vec<f64>) -> &'a [f64] {
        match self {
            CostMatrix::Dense(ct) => ct.row(j),
            CostMatrix::Factored(f) => {
                buf.resize(f.m(), 0.0);
                f.fill_col(j, buf);
                buf
            }
        }
    }

    /// Resident bytes of the backend (what the serving engine's dataset
    /// cache accounts — the factored entry charges coordinates, not the
    /// m×n matrix it never materializes).
    pub fn bytes(&self) -> usize {
        match self {
            CostMatrix::Dense(ct) => std::mem::size_of::<f64>() * ct.rows() * ct.cols(),
            CostMatrix::Factored(f) => f.bytes(),
        }
    }
}

impl Clone for CostMatrix {
    fn clone(&self) -> Self {
        match self {
            CostMatrix::Dense(ct) => CostMatrix::Dense(ct.clone()),
            CostMatrix::Factored(f) => CostMatrix::Factored(FactoredCost {
                xs: f.xs.clone(),
                xt: f.xt.clone(),
                xs_sq: f.xs_sq.clone(),
                xt_sq: f.xt_sq.clone(),
                inv_max: f.inv_max,
            }),
        }
    }
}

impl std::fmt::Debug for CostMatrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CostMatrix::Dense(ct) => {
                f.debug_struct("Dense").field("shape_t", &ct.shape()).finish()
            }
            CostMatrix::Factored(fc) => f
                .debug_struct("Factored")
                .field("m", &fc.m())
                .field("n", &fc.n())
                .field("d", &fc.dim())
                .finish(),
        }
    }
}

/// Per-entry byte budget of one chunk's [`TileRing`]. Chunk count is
/// capped at [`crate::pool::MAX_FIXED_CHUNKS`] (32), so the whole-solve
/// ring footprint is bounded at 32 MiB regardless of problem size —
/// the factored memory model stays O((m+n)·d + const).
pub const TILE_RING_BUDGET_BYTES: usize = 1 << 20;

/// Resolve the effective per-chunk tile-ring budget in bytes: the
/// explicit KiB value when given, else `GRPOT_TILE_RING_KIB`, else
/// [`TILE_RING_BUDGET_BYTES`]. A malformed or zero env value is an
/// error (the CLI launch-validates it; library callers on infallible
/// paths fall back to the default instead).
pub fn resolve_tile_ring_bytes(explicit_kib: Option<usize>) -> Result<usize> {
    if let Some(kib) = explicit_kib {
        return Ok(kib.max(1) * 1024);
    }
    match std::env::var("GRPOT_TILE_RING_KIB") {
        Ok(s) => match s.trim().parse::<usize>() {
            Ok(kib) if kib >= 1 => Ok(kib * 1024),
            _ => Err(err!(
                "GRPOT_TILE_RING_KIB must be a positive integer (KiB), got '{s}'"
            )),
        },
        Err(_) => Ok(TILE_RING_BUDGET_BYTES),
    }
}

/// A small FIFO cache of synthesized (panel, group) cost tiles, one per
/// column-chunk scratch slot (so no sharing, no locks, and the
/// deterministic chunk→slot assignment is untouched). Entries hold
/// every full quad of one (panel, group) pair consecutively in the
/// packed `[i][lane]` layout; keys are `(panel_start, group)`.
///
/// Tiles are a pure function of the (immutable) cost data, so entries
/// stay valid across evaluations — the steady state of an L-BFGS solve
/// synthesizes each surviving tile once and replays it from the ring,
/// while tiles of screened-out groups are never synthesized at all.
/// When the working set outgrows the budget the FIFO cursor evicts the
/// oldest entries and the walk re-synthesizes on the next visit.
pub struct TileRing {
    /// f64 capacity of one entry slot (`PANEL_COLS × max_group`).
    stride: usize,
    /// Number of entry slots (≥ 2, sized by [`TILE_RING_BUDGET_BYTES`]).
    capacity: usize,
    /// Backing store, `capacity × stride`, allocated on first use so
    /// scalar-dispatch solves never pay for it.
    data: Vec<f64>,
    /// Key resident in each slot (`None` = empty).
    keys: Vec<Option<(usize, usize)>>,
    map: HashMap<(usize, usize), usize>,
    /// Next eviction victim (FIFO).
    cursor: usize,
    /// Entries synthesized over the ring's lifetime (diagnostics).
    built: u64,
}

impl TileRing {
    /// A ring whose entries hold up to `stride` f64s each, with as many
    /// slots as [`TILE_RING_BUDGET_BYTES`] allows (at least 2, so an
    /// eviction can never thrash a single-entry ring within one panel).
    pub fn new(stride: usize) -> TileRing {
        Self::with_budget(stride, TILE_RING_BUDGET_BYTES)
    }

    /// [`TileRing::new`] with an explicit per-slot byte budget (the
    /// `--tile-ring-kib` / `GRPOT_TILE_RING_KIB` knob). Capacity stays
    /// at least 2 regardless of how small the budget is, so eviction can
    /// never thrash a single-entry ring within one panel. The budget
    /// changes only *retention* — which tiles are resident when the walk
    /// returns — never the synthesized values, so solves are byte-equal
    /// at every budget (only `tiles_built` moves).
    pub fn with_budget(stride: usize, budget_bytes: usize) -> TileRing {
        let stride = stride.max(1);
        let capacity = (budget_bytes / (stride * std::mem::size_of::<f64>())).max(2);
        TileRing {
            stride,
            capacity,
            data: Vec::new(),
            keys: vec![None; capacity],
            map: HashMap::new(),
            cursor: 0,
            built: 0,
        }
    }

    /// Number of entry slots.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Entries currently resident.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// Entries synthesized (fill calls) over the ring's lifetime.
    pub fn total_built(&self) -> u64 {
        self.built
    }

    /// Resident bytes of the backing store.
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f64>()
    }

    /// Look up the tile for `key`, synthesizing `len` f64s via `fill`
    /// on a miss (evicting the FIFO-oldest entry if the ring is full).
    /// Returns the tile slice and whether this call built it.
    pub fn entry(
        &mut self,
        key: (usize, usize),
        len: usize,
        fill: impl FnOnce(&mut [f64]),
    ) -> (&[f64], bool) {
        debug_assert!(len <= self.stride, "tile larger than ring stride");
        if let Some(&slot) = self.map.get(&key) {
            let base = slot * self.stride;
            return (&self.data[base..base + len], false);
        }
        if self.data.is_empty() {
            self.data = vec![0.0; self.capacity * self.stride];
        }
        let slot = self.cursor;
        self.cursor = (self.cursor + 1) % self.capacity;
        if let Some(old) = self.keys[slot].take() {
            self.map.remove(&old);
        }
        let base = slot * self.stride;
        fill(&mut self.data[base..base + len]);
        self.keys[slot] = Some(key);
        self.map.insert(key, slot);
        self.built += 1;
        (&self.data[base..base + len], true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cost_mode_parse_roundtrip_and_errors() {
        for m in [CostMode::Auto, CostMode::Dense, CostMode::Factored] {
            assert_eq!(CostMode::parse(m.name()).unwrap(), m);
        }
        assert_eq!(CostMode::parse("lowrank").unwrap(), CostMode::Factored);
        let e = CostMode::parse("sparse").unwrap_err();
        assert!(e.to_string().contains("unknown cost mode"), "{e}");
        // Explicit modes resolve to themselves regardless of env.
        assert_eq!(CostMode::Dense.resolve().unwrap(), CostMode::Dense);
        assert_eq!(CostMode::Factored.resolve().unwrap(), CostMode::Factored);
    }

    #[test]
    fn factored_entries_match_dense_pipeline_bitwise() {
        let mut rng = crate::rng::Pcg64::new(0xC057);
        let (m, n, d) = (7, 9, 3);
        let xs = Mat::from_fn(m, d, |_, _| rng.uniform(-1.0, 2.0));
        let xt = Mat::from_fn(n, d, |_, _| rng.uniform(-1.5, 1.0));
        let mut dense = linalg::sq_euclidean_cost(&xs, &xt);
        linalg::normalize_by_max(&mut dense);
        let f = FactoredCost::build(xs, xt);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(
                    f.entry(i, j).to_bits(),
                    dense[(i, j)].to_bits(),
                    "entry ({i}, {j})"
                );
            }
        }
        let mut col = vec![0.0; m];
        f.fill_col(4, &mut col);
        for i in 0..m {
            assert_eq!(col[i].to_bits(), dense[(i, 4)].to_bits());
        }
        let mut seg = vec![0.0; 3];
        f.fill_seg(2, 1..4, &mut seg);
        for (k, i) in (1..4).enumerate() {
            assert_eq!(seg[k].to_bits(), dense[(i, 2)].to_bits());
        }
        // A degenerate all-zero cost keeps inv_max at 1.0 (no scaling),
        // matching normalize_by_max's skip.
        let z = FactoredCost::build(Mat::zeros(2, 2), Mat::zeros(3, 2));
        assert_eq!(z.entry(1, 2), 0.0);
    }

    #[test]
    fn panel_group_tiles_use_packed_layout() {
        let mut rng = crate::rng::Pcg64::new(0x7171);
        let (m, n, d) = (6, 16, 2);
        let xs = Mat::from_fn(m, d, |_, _| rng.uniform(0.0, 1.0));
        let xt = Mat::from_fn(n, d, |_, _| rng.uniform(0.0, 1.0));
        let f = FactoredCost::build(xs, xt);
        let (j0, quads, rows) = (8usize, 2usize, 1..4);
        let g = rows.len();
        let mut buf = vec![0.0; quads * LANES * g];
        f.fill_panel_group(j0, quads, rows.clone(), &mut buf);
        for q in 0..quads {
            for (k, i) in rows.clone().enumerate() {
                for t in 0..LANES {
                    assert_eq!(
                        buf[q * LANES * g + k * LANES + t].to_bits(),
                        f.entry(i, j0 + q * LANES + t).to_bits()
                    );
                }
            }
        }
    }

    #[test]
    fn tile_ring_budget_controls_capacity() {
        let stride = 8;
        let big = TileRing::with_budget(stride, 1 << 20);
        let small = TileRing::with_budget(stride, 4 * stride * std::mem::size_of::<f64>());
        assert!(big.capacity() > small.capacity());
        assert_eq!(small.capacity(), 4);
        // Floor of 2 even for a degenerate budget.
        assert_eq!(TileRing::with_budget(stride, 0).capacity(), 2);
        // The default constructor is the fixed budget.
        assert_eq!(TileRing::new(stride).capacity(), big.capacity());
    }

    #[test]
    fn tile_ring_caches_evicts_and_refills() {
        let stride = 4;
        let mut ring = TileRing::new(stride);
        // Shrink capacity artificially by exercising more keys than the
        // budget allows is impractical here (the budget admits many 4-f64
        // slots), so drive eviction directly through a tiny ring.
        let mut tiny = TileRing { capacity: 2, keys: vec![None; 2], ..TileRing::new(stride) };
        let fills = std::cell::Cell::new(0u32);
        let mut get = |ring: &mut TileRing, key: (usize, usize), val: f64| {
            let (slice, built) = ring.entry(key, 3, |buf| {
                fills.set(fills.get() + 1);
                buf.fill(val);
            });
            (slice.to_vec(), built)
        };
        let (v, built) = get(&mut tiny, (0, 0), 1.0);
        assert!(built);
        assert_eq!(v, vec![1.0; 3]);
        let (_, built) = get(&mut tiny, (8, 1), 2.0);
        assert!(built);
        // Hit: no new fill, cached bytes returned.
        let (v, built) = get(&mut tiny, (0, 0), 99.0);
        assert!(!built);
        assert_eq!(v, vec![1.0; 3]);
        assert_eq!(fills.get(), 2);
        // Third distinct key evicts the FIFO-oldest entry (key (0, 0)).
        let (_, built) = get(&mut tiny, (16, 0), 3.0);
        assert!(built);
        assert_eq!(tiny.len(), 2);
        // Refill after eviction: (0, 0) is gone and must be rebuilt.
        let (v, built) = get(&mut tiny, (0, 0), 4.0);
        assert!(built);
        assert_eq!(v, vec![4.0; 3]);
        assert_eq!(tiny.total_built(), 4);
        // The budget-sized ring never evicts within its capacity.
        for k in 0..ring.capacity().min(64) {
            let (_, built) = ring.entry((k, 0), stride, |b| b.fill(k as f64));
            assert!(built);
        }
        for k in 0..ring.capacity().min(64) {
            let (slice, built) = ring.entry((k, 0), stride, |b| b.fill(-1.0));
            assert!(!built, "entry {k} should be resident");
            assert_eq!(slice[0], k as f64);
        }
    }
}
