//! Trace IDs, span guards and the Chrome trace-event exporter.
//!
//! Trace IDs are minted at admission ([`next_trace_id`] — one relaxed
//! `fetch_add`, always on, no allocation) and threaded through the
//! ticket → batch → engine worker → solve chain. Spans are recorded via
//! RAII guards ([`Span`]) or retroactively from timestamps the caller
//! already holds ([`record_span_at`] — e.g. queue wait, measured from
//! the ticket's existing `submitted` instant, so the hot path pays zero
//! extra clock reads). When tracing is off every entry point is a
//! single relaxed atomic load.

use super::ring;
use crate::jsonlite::Value;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// The static span taxonomy. Span names are interned as indices into
/// [`names::ALL`] so the record path never touches a string.
pub mod names {
    /// Submit → dequeue, per ticket (recorded retroactively at dequeue).
    pub const QUEUE_WAIT: u32 = 0;
    /// One micro-batch through `handle_batch` (triage + dataset + jobs).
    pub const ENGINE_BATCH: u32 = 1;
    /// One deduplicated solve job inside a batch.
    pub const ENGINE_SOLVE: u32 = 2;
    /// One Algorithm-1 solver run (full mode).
    pub const SOLVE: u32 = 3;
    /// One `r`-iteration block + working-set refresh (full mode).
    pub const OUTER_ROUND: u32 = 4;
    /// Dataset generation + problem preparation for a cold cache miss.
    pub const DATASET_BUILD: u32 = 5;

    pub const ALL: [&str; 6] = [
        "queue.wait",
        "engine.batch",
        "engine.solve",
        "solve",
        "solve.outer_round",
        "engine.dataset_build",
    ];
}

/// Mint a fresh nonzero trace ID. Always on (whether or not spans are
/// recorded) so responses can echo an ID in every mode.
pub fn next_trace_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Process trace epoch: all span timestamps are nanoseconds since this
/// instant. Initialized on the first *enabled* span — the off path
/// never touches it.
fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn ns_since_epoch(t: Instant) -> u64 {
    t.saturating_duration_since(epoch()).as_nanos() as u64
}

/// RAII span guard: records `[start, drop)` into the calling thread's
/// ring. Construction when tracing is off is one relaxed load — no
/// clock read, no allocation, nothing on drop.
pub struct Span {
    inner: Option<SpanInner>,
}

struct SpanInner {
    name_id: u32,
    trace_id: u64,
    start: Instant,
}

impl Span {
    /// Start a request-level span (recorded in `spans` and `full` mode).
    pub fn start(name_id: u32, trace_id: u64) -> Span {
        if !super::enabled() {
            return Span { inner: None };
        }
        Span { inner: Some(SpanInner { name_id, trace_id, start: Instant::now() }) }
    }

    /// Start a solver-internal span (recorded in `full` mode only).
    pub fn start_full(name_id: u32, trace_id: u64) -> Span {
        if !super::full_enabled() {
            return Span { inner: None };
        }
        Span { inner: Some(SpanInner { name_id, trace_id, start: Instant::now() }) }
    }

    /// Whether this guard will record on drop.
    pub fn is_recording(&self) -> bool {
        self.inner.is_some()
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(inner) = self.inner.take() {
            let start_ns = ns_since_epoch(inner.start);
            let dur_ns = inner.start.elapsed().as_nanos() as u64;
            ring::with_local(|ring, tid| {
                ring.record(inner.name_id, tid, inner.trace_id, start_ns, dur_ns);
            });
        }
    }
}

/// Record a span retroactively from instants the caller already holds
/// (e.g. queue wait from the ticket's `submitted` timestamp). No-op
/// when tracing is off.
pub fn record_span_at(name_id: u32, trace_id: u64, start: Instant, end: Instant) {
    if !super::enabled() {
        return;
    }
    let start_ns = ns_since_epoch(start);
    let dur_ns = end.saturating_duration_since(start).as_nanos() as u64;
    ring::with_local(|ring, tid| {
        ring.record(name_id, tid, trace_id, start_ns, dur_ns);
    });
}

/// Drain every thread's ring into Chrome trace-event-format JSON
/// (`{"traceEvents": [...]}`; complete `"ph": "X"` events with
/// microsecond timestamps) — loads directly in `chrome://tracing` and
/// Perfetto. Non-destructive: rings keep their contents.
pub fn drain_chrome_json() -> Value {
    let mut events = ring::snapshot_all();
    events.sort_by_key(|e| (e.start_ns, e.tid));
    let items: Vec<Value> = events
        .iter()
        .map(|e| {
            let name = names::ALL
                .get(e.name_id as usize)
                .copied()
                .unwrap_or("unknown");
            Value::obj()
                .set("name", name)
                .set("ph", "X")
                .set("ts", e.start_ns as f64 / 1e3)
                .set("dur", e.dur_ns as f64 / 1e3)
                .set("pid", 1u64)
                .set("tid", e.tid as u64)
                .set("args", Value::obj().set("trace_id", e.trace_id))
        })
        .collect();
    Value::obj()
        .set("traceEvents", Value::Arr(items))
        .set("displayTimeUnit", "ms")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_ids_are_unique_and_nonzero() {
        let a = next_trace_id();
        let b = next_trace_id();
        assert_ne!(a, 0);
        assert_ne!(a, b);
    }

    #[test]
    fn span_off_mode_records_nothing() {
        // Unit tests leave the global mode at Off.
        let s = Span::start(names::SOLVE, 1);
        assert!(!s.is_recording());
        let f = Span::start_full(names::OUTER_ROUND, 1);
        assert!(!f.is_recording());
    }

    #[test]
    fn chrome_json_shape() {
        let doc = drain_chrome_json();
        assert!(doc.get("traceEvents").and_then(Value::as_arr).is_some());
    }
}
