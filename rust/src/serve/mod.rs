//! The serving engine: the layer between the TCP front-end and the OT
//! solvers, built for a serving workload (many small solves against a
//! handful of datasets, heavy key reuse) rather than one-shot research
//! runs.
//!
//! Four pieces, one per module:
//!
//! * [`queue`] — admission control: a capacity-bounded request queue
//!   with per-request deadlines. Overload is rejected *at submit time*
//!   with a structured error ([`engine::RejectReason::QueueFull`])
//!   instead of piling up unbounded work; requests whose deadline
//!   passes while queued are answered with
//!   [`engine::RejectReason::DeadlineExceeded`] without ever touching a
//!   solver.
//! * [`batcher`] — micro-batching: concurrent requests against the same
//!   dataset spec are coalesced so the cost matrix / group structure is
//!   built (or fetched) once per batch, and *identical* (γ, ρ, method)
//!   requests within a batch are solved once and fanned out to every
//!   waiter.
//! * [`cache`] — the warm-start dual cache: recent dual vectors keyed by
//!   (dataset, γ, ρ) under an LRU byte budget. A hit seeds L-BFGS from
//!   the cached (near-)optimum; the paper's safe-screening guarantees
//!   hold from any starting point (Theorem 2), so warm starts change
//!   iteration counts, never results.
//! * [`engine`] — the engine itself: worker threads consuming batches
//!   from the queue, solving via [`crate::coordinator::sweep::solve`]
//!   and publishing per-request metrics (latency percentiles, queue
//!   depth, warm hit/miss, rejections).
//!
//! [`loadgen`] adds the closed-loop load generator behind
//! `grpot bench-serve` and `cargo bench --bench bench_serve`.
//!
//! On top of these the engine enforces deadlines *mid-solve* through
//! cooperative [`crate::fault::CancelToken`]s (an admitted solve stops
//! at the next iteration checkpoint once its deadline passes),
//! quarantines persistently failing dataset keys behind a per-key
//! circuit breaker ([`engine::RejectReason::Quarantined`]), and sheds
//! load at admission when the estimated queue wait already exceeds a
//! request's deadline ([`engine::RejectReason::Overloaded`]).

pub mod batcher;
pub mod cache;
pub mod engine;
pub mod loadgen;
pub mod queue;

pub use cache::DualCache;
pub use engine::{CachedProblem, Engine, EngineReply, RejectReason, SolveRequest};

use crate::ot::solve::SolveOptions;
use std::time::Duration;

/// Engine tuning knobs. The defaults suit the in-repo demo datasets;
/// each knob is surfaced as a `grpot serve` / `grpot bench-serve` flag
/// (the inner L-BFGS options via `--max-iters`).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Solver worker threads — the maximum number of concurrent solves.
    pub workers: usize,
    /// Admission-queue capacity; submits beyond it are rejected.
    pub queue_capacity: usize,
    /// Deadline applied to requests that don't carry their own.
    /// `None` = no default deadline.
    pub default_deadline: Option<Duration>,
    /// Maximum requests coalesced into one micro-batch.
    pub max_batch: usize,
    /// Warm-start cache budget in bytes (0 disables caching).
    pub warm_cache_bytes: usize,
    /// Maximum datasets kept in the problem cache (cost matrix + pair);
    /// least-recently-used entries are evicted beyond this.
    pub problem_cache_entries: usize,
    /// Master switch for warm starts (per-request opt-out on top).
    pub warm_start: bool,
    /// Maximum hyperparameter distance `√((Δln γ)² + (Δρ)²)` at which a
    /// cached dual still seeds a solve.
    pub warm_radius: f64,
    /// Per-solve options for every engine solve (snapshot interval `r`,
    /// L-BFGS caps, SIMD policy, default regularizer — a request's
    /// explicit `regularizer` wins). `solve.threads` is the intra-solve
    /// oracle worker count (deterministic: results are bit-identical to
    /// serial); the engine clamps the effective value so
    /// `workers × solve.threads` never exceeds
    /// [`ServeConfig::core_budget`] — micro-batched serving and intra-op
    /// parallelism compose instead of oversubscribing. `solve.gamma`/
    /// `solve.rho`/`solve.warm_start`/`solve.ctx` are per-request /
    /// per-worker and overridden by the engine.
    pub solve: SolveOptions,
    /// Core budget for the `workers × solve.threads` product;
    /// 0 = autodetect via `std::thread::available_parallelism`.
    pub core_budget: usize,
    /// Circuit breaker: consecutive dataset-build/solve *infrastructure*
    /// failures (errors or panics — not solver non-convergence) on one
    /// dataset key before the key is quarantined. 0 disables the
    /// breaker.
    pub breaker_threshold: u32,
    /// How long a tripped breaker fast-fails its key before letting one
    /// half-open probe request through.
    pub breaker_cooldown: Duration,
    /// Overload load-shedding: reject at admission when the estimated
    /// queue wait (queue depth / workers × mean solve seconds) already
    /// exceeds a request's deadline — the solve could only ever be
    /// triaged as expired after burning queue capacity.
    pub shed: bool,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            workers: 4,
            queue_capacity: 128,
            default_deadline: None,
            max_batch: 16,
            warm_cache_bytes: 64 << 20,
            problem_cache_entries: 32,
            warm_start: true,
            warm_radius: 2.0,
            solve: SolveOptions::new(),
            core_budget: 0,
            breaker_threshold: 3,
            breaker_cooldown: Duration::from_secs(5),
            shed: true,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_sane() {
        let cfg = ServeConfig::default();
        assert!(cfg.workers >= 1);
        assert!(cfg.queue_capacity >= cfg.workers);
        assert!(cfg.max_batch >= 1);
        assert!(cfg.warm_start);
        assert!(cfg.warm_cache_bytes > 0);
        assert_eq!(cfg.solve.threads, 1, "serving defaults to serial solves");
        assert_eq!(cfg.core_budget, 0, "core budget autodetects by default");
        assert_eq!(cfg.solve.regularizer, None, "requests pick the regularizer");
        assert!(cfg.breaker_threshold >= 1, "breaker on by default");
        assert!(cfg.breaker_cooldown > Duration::ZERO);
        assert!(cfg.shed, "load shedding on by default");
    }
}
