//! Quickstart: solve one group-sparse regularized OT problem and verify
//! the paper's core claims on a small instance:
//!
//! 1. ours == origin objective (Theorem 2),
//! 2. ours skips most gradient computations,
//! 3. the plan is group-sparse (Figure 1's structure).
//!
//! Run: `cargo run --release --example quickstart`

use grpot::ot::plan::recover_plan;
use grpot::prelude::*;

fn main() {
    // 10 classes × 10 samples per class on each domain — the smallest
    // point of the paper's Fig. 2 grid.
    let pair = grpot::data::synthetic::controlled(10, 10, 0xC0FFEE);
    let prob = OtProblem::from_dataset(&pair);
    println!(
        "problem: m={} n={} |L|={} (classes)",
        prob.m(),
        prob.n(),
        prob.groups.num_groups()
    );

    let cfg = FastOtConfig { gamma: 0.1, rho: 0.8, ..Default::default() };

    let fast = solve_fast_ot(&prob, &cfg);
    let origin = solve_origin(&prob, &cfg);

    println!("\n== Theorem 2: identical optimization results ==");
    println!("ours   : dual objective = {:.12}", fast.dual_objective);
    println!("origin : dual objective = {:.12}", origin.dual_objective);
    assert_eq!(fast.dual_objective, origin.dual_objective);
    assert_eq!(fast.x, origin.x, "identical solutions, not just objectives");

    println!("\n== gradient computations ==");
    let f = &fast.stats;
    let o = &origin.stats;
    println!("origin : {:>10} group gradients", o.grads_computed);
    println!(
        "ours   : {:>10} computed, {:>10} skipped ({:.1}% skipped)",
        f.grads_computed,
        f.grads_skipped,
        100.0 * f.grads_skipped as f64 / (f.grads_computed + f.grads_skipped).max(1) as f64
    );
    println!(
        "wall   : origin {:.3}s vs ours {:.3}s ({:.2}x)",
        origin.wall_time_s,
        fast.wall_time_s,
        origin.wall_time_s / fast.wall_time_s.max(1e-9)
    );

    println!("\n== plan structure ==");
    let plan = recover_plan(&prob, &cfg.params(), &fast.x);
    println!("transport cost      : {:.6}", plan.transport_cost(&prob));
    println!("plan density        : {:.4}", plan.density(1e-12));
    println!("group sparsity      : {:.4}", plan.group_sparsity(&prob, 1e-12));
    println!(
        "single-class columns: {:.4} (Fig. 1: mass reaches each target from one class)",
        plan.single_class_columns(&prob, 1e-12)
    );
    let (va, vb) = plan.marginal_violation(&prob);
    println!("marginal violation  : ({va:.2e}, {vb:.2e})");
    println!("\nquickstart OK");
}
