//! Batched-solve byte-equality, end to end: `solve_batched` over K
//! independent (γ, ρ) problems on one dataset must reproduce each
//! problem's sequential `fastot::solve` *byte-for-byte* — solution,
//! objective, iteration count, stop reason and every `OracleStats`
//! counter — across the full matrix of K ∈ {1, 3, 4, 7} (one lane, a
//! partial group, a full SIMD group, full + remainder), scalar and
//! runtime-dispatched vector kernels, 1 and 4 oracle threads, dense and
//! factored cost backends, cold and warm starts. The one deliberately
//! excluded counter is `tiles_built`: the fused pass shares tile
//! staging across lanes, so the factored backend synthesizes each
//! surviving segment once per group instead of once per lane — the
//! whole point of batching, and a throughput diagnostic rather than
//! solver output.
//!
//! The `GRPOT_BATCH_K=4` CI shard re-runs this suite (plus the serving
//! engine suite) with env-defaulted batching on; every comparison here
//! drives `solve_batched` explicitly, so the assertions stay genuine
//! batched-vs-sequential crosses under any env.

use grpot::linalg::Mat;
use grpot::ot::batch::solve_batched;
use grpot::ot::cost::CostMode;
use grpot::ot::dual::OtProblem;
use grpot::ot::fastot::{self, FastOtResult};
use grpot::ot::regularizer::RegKind;
use grpot::ot::solve::SolveOptions;
use grpot::rng::Pcg64;
use grpot::simd::SimdMode;
use grpot::solvers::StopReason;

/// One point cloud built on the requested cost backend: `l` groups of
/// `g` source points, `n` targets, dimension `d`.
fn point_problem(seed: u64, l: usize, g: usize, n: usize, d: usize, mode: CostMode) -> OtProblem {
    let mut rng = Pcg64::new(seed);
    let m = l * g;
    let xs = Mat::from_fn(m, d, |_, _| rng.uniform(-1.0, 1.0));
    let xt = Mat::from_fn(n, d, |_, _| rng.uniform(-1.0, 1.0));
    let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
    OtProblem::try_from_points(&xs, &labels, &xt, mode).expect("problem build")
}

/// K heterogeneous lanes: every lane gets its own (γ, ρ) off a grid
/// spanning the skip-heavy and dense regimes.
fn grid_opts(k: usize, threads: usize, simd: SimdMode, warm: Option<&[f64]>) -> Vec<SolveOptions> {
    const GAMMAS: [f64; 7] = [0.2, 0.7, 1.5, 4.0, 0.05, 9.0, 0.4];
    const RHOS: [f64; 7] = [0.3, 0.6, 0.8, 0.45, 0.2, 0.7, 0.55];
    (0..k)
        .map(|i| {
            let mut o = SolveOptions::new()
                .gamma(GAMMAS[i % 7])
                .rho(RHOS[i % 7])
                .max_iters(60)
                .threads(threads)
                .simd(simd)
                .regularizer(RegKind::GroupLasso);
            if let Some(x0) = warm {
                o = o.warm_start(x0.to_vec());
            }
            o
        })
        .collect()
}

/// Field-wise byte equality *except* `tiles_built` (see module doc).
fn assert_lane_eq(batched: &FastOtResult, seq: &FastOtResult, what: &str) {
    assert_eq!(batched.x, seq.x, "{what}: solution bytes");
    assert_eq!(batched.dual_objective, seq.dual_objective, "{what}: objective");
    assert_eq!(batched.iterations, seq.iterations, "{what}: iterations");
    assert_eq!(batched.outer_rounds, seq.outer_rounds, "{what}: outer rounds");
    assert_eq!(batched.stop, seq.stop, "{what}: stop reason");
    assert_eq!(batched.method, seq.method, "{what}: method label");
    let (a, b) = (&batched.stats, &seq.stats);
    assert_eq!(a.evals, b.evals, "{what}: evals");
    assert_eq!(a.grads_computed, b.grads_computed, "{what}: grads_computed");
    assert_eq!(a.grads_skipped, b.grads_skipped, "{what}: grads_skipped");
    assert_eq!(a.ub_checks, b.ub_checks, "{what}: ub_checks");
    assert_eq!(a.ws_hits, b.ws_hits, "{what}: ws_hits");
    assert_eq!(a.per_eval_grads, b.per_eval_grads, "{what}: per_eval_grads");
}

/// The acceptance-criterion matrix: every batched lane byte-equals its
/// sequential solve at any K, dispatch, thread count, backend and
/// start point.
#[test]
fn batched_matches_sequential_across_full_matrix() {
    for mode in [CostMode::Dense, CostMode::Factored] {
        let prob = point_problem(0xBA7C, 4, 3, 21, 3, mode);
        let mut rng = Pcg64::new(7);
        let x0: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.2, 0.3)).collect();
        for k in [1usize, 3, 4, 7] {
            for threads in [1usize, 4] {
                for simd in [SimdMode::Scalar, SimdMode::Auto] {
                    for warm in [None, Some(&x0[..])] {
                        let opts = grid_opts(k, threads, simd, warm);
                        let batched = solve_batched(&prob, &opts).expect("batched solve");
                        assert_eq!(batched.len(), k);
                        for (i, opt) in opts.iter().enumerate() {
                            let seq = fastot::solve(&prob, opt).expect("sequential solve");
                            let what = format!(
                                "{mode:?} K={k} lane={i} threads={threads} simd={simd:?} warm={}",
                                warm.is_some()
                            );
                            assert_lane_eq(&batched[i], &seq, &what);
                        }
                    }
                }
            }
        }
    }
}

/// Mixed convergence: lanes with wildly different iteration caps retire
/// at different rounds, and the stragglers keep solving — every lane
/// still byte-equals its sequential solve, early retirees included.
#[test]
fn straggler_lanes_survive_early_retirees() {
    let prob = point_problem(0xBA7D, 3, 4, 17, 2, CostMode::Dense);
    let caps = [3usize, 80, 9, 80];
    let opts: Vec<SolveOptions> = caps
        .iter()
        .enumerate()
        .map(|(i, &cap)| {
            SolveOptions::new()
                .gamma(0.4 + 0.3 * i as f64)
                .rho(0.25 + 0.15 * i as f64)
                .max_iters(cap)
                .regularizer(RegKind::GroupLasso)
        })
        .collect();
    let batched = solve_batched(&prob, &opts).expect("batched solve");
    let mut stops = Vec::new();
    for (i, opt) in opts.iter().enumerate() {
        let seq = fastot::solve(&prob, opt).expect("sequential solve");
        assert_lane_eq(&batched[i], &seq, &format!("straggler lane {i}"));
        stops.push(batched[i].stop);
    }
    // The matrix is only meaningful if retirement really was staggered.
    assert!(
        stops.contains(&StopReason::MaxIters),
        "at least one lane must hit its tiny cap: {stops:?}"
    );
    assert!(
        stops.iter().any(|s| *s != StopReason::MaxIters),
        "at least one lane must outlive the capped ones: {stops:?}"
    );
}

/// Mid-batch cancellation: a cancelled lane retires at its first
/// checkpoint exactly like its sequential solve would, and its
/// batchmates are entirely undisturbed.
#[test]
fn cancelled_lane_matches_sequential_cancellation() {
    let prob = point_problem(0xBA7E, 3, 3, 13, 2, CostMode::Factored);
    let token = grpot::fault::CancelToken::new();
    token.cancel();
    let mut opts = grid_opts(4, 1, SimdMode::Auto, None);
    opts[2] = opts[2].clone().cancel(token.clone());
    let batched = solve_batched(&prob, &opts).expect("batched solve");
    for (i, opt) in opts.iter().enumerate() {
        let seq = fastot::solve(&prob, opt).expect("sequential solve");
        assert_lane_eq(&batched[i], &seq, &format!("cancel lane {i}"));
    }
    assert_eq!(batched[2].stop, StopReason::Cancelled);
    assert_eq!(batched[2].iterations, 0);
}

/// The `--tile-ring-kib` knob moves only tile *retention*: a factored
/// batch squeezed through a deliberately tiny ring budget stays
/// byte-equal to the default-budget batch — only `tiles_built` may
/// grow (re-synthesis after eviction).
#[test]
fn tile_ring_budget_never_changes_solver_output() {
    let prob = point_problem(0xBA7F, 4, 3, 19, 3, CostMode::Factored);
    let base = grid_opts(4, 1, SimdMode::Auto, None);
    let squeezed: Vec<SolveOptions> =
        base.iter().map(|o| o.clone().tile_ring_kib(4)).collect();
    let full = solve_batched(&prob, &base).expect("default budget");
    let tiny = solve_batched(&prob, &squeezed).expect("tiny budget");
    for i in 0..base.len() {
        assert_lane_eq(&tiny[i], &full[i], &format!("ring lane {i}"));
        assert!(
            tiny[i].stats.tiles_built >= full[i].stats.tiles_built,
            "lane {i}: a smaller ring can only re-synthesize more"
        );
    }
}
