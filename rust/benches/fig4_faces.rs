//! Figure 4: gain on the 12 PIE face-recognition adaptation tasks
//! (68 classes, 4 pose domains). Paper: up to 3.7×. Domain sizes are
//! scaled (quick 0.04 / full 0.12 of the paper's 3332/1629/1632/1632).

mod common;

use common::*;
use grpot::data::faces;

fn main() {
    banner("fig4: PIE face tasks");
    // Gains need non-trivial per-class group sizes (paper: g ≈ 24–49);
    // below ~0.1 the screening overhead dominates tiny g ≈ 2 groups and
    // gains drop under 1× — see EXPERIMENTS.md §Fig4.
    let scale = size3(0.03, 0.1, 0.3);
    let tasks = size3(2, 12, 12);
    let gammas = gamma_grid();
    let rhos = rho_grid();

    let mut blocks = Vec::new();
    for pair in faces::all_tasks(scale, 0xF164).into_iter().take(tasks) {
        let prob = problem_of(&pair);
        println!("task {} (m={}, n={}) …", pair.task_name(), prob.m(), prob.n());
        let rows = gain_sweep(&prob, &gammas, &rhos, 10);
        for r in &rows {
            println!("  gamma={:<8} gain={:.2}x", r.gamma, r.gain);
            assert!(r.objectives_match);
        }
        blocks.push((pair.task_name(), rows));
    }
    emit_gain_table(
        "Fig. 4 — processing-time gain on face recognition tasks (12 PIE pairs)",
        "fig4_faces",
        &blocks,
    );
}
