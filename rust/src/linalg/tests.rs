use super::*;

fn assert_close(a: f64, b: f64, tol: f64) {
    assert!((a - b).abs() <= tol, "{a} vs {b}");
}

#[test]
fn mat_indexing_row_major() {
    let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
    assert_eq!(m[(0, 0)], 1.0);
    assert_eq!(m[(0, 2)], 3.0);
    assert_eq!(m[(1, 0)], 4.0);
    assert_eq!(m.row(1), &[4., 5., 6.]);
    assert_eq!(m.col_to_vec(1), vec![2., 5.]);
}

#[test]
fn mat_transpose_roundtrip() {
    let m = Mat::from_fn(3, 5, |i, j| (i * 10 + j) as f64);
    let t = m.transpose();
    assert_eq!(t.shape(), (5, 3));
    assert_eq!(t.transpose(), m);
    for i in 0..3 {
        for j in 0..5 {
            assert_eq!(m[(i, j)], t[(j, i)]);
        }
    }
}

#[test]
fn tiled_transpose_equals_naive() {
    // Shapes straddling the 32-tile boundary in every way: smaller,
    // exact multiples, one-over, ragged both dims, degenerate vectors.
    for (rows, cols) in
        [(1usize, 1usize), (3, 5), (31, 33), (32, 32), (33, 31), (64, 64), (70, 37), (1, 100)]
    {
        let m = Mat::from_fn(rows, cols, |i, j| (i * 131 + j * 7) as f64 * 0.25 - 3.0);
        let tiled = m.transpose();
        let naive = m.transpose_naive();
        assert_eq!(tiled.shape(), (cols, rows));
        assert_eq!(tiled, naive, "mismatch at {rows}x{cols}");
    }
}

#[test]
fn mat_matvec_and_t() {
    let m = Mat::from_vec(2, 3, vec![1., 2., 3., 4., 5., 6.]);
    assert_eq!(m.matvec(&[1., 0., -1.]), vec![-2., -2.]);
    assert_eq!(m.matvec_t(&[1., 1.]), vec![5., 7., 9.]);
}

#[test]
fn mat_matmul_identity() {
    let m = Mat::from_fn(4, 4, |i, j| ((i + 1) * (j + 2)) as f64);
    let i4 = Mat::eye(4);
    assert_eq!(m.matmul(&i4), m);
    assert_eq!(i4.matmul(&m), m);
}

#[test]
fn mat_sums() {
    let m = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
    assert_eq!(m.sum(), 10.0);
    assert_eq!(m.row_sums(), vec![3., 7.]);
    assert_eq!(m.col_sums(), vec![4., 6.]);
    assert_eq!(m.max_abs(), 4.0);
    assert_eq!(m.count_nonzero(0.0), 4);
}

#[test]
fn dot_matches_naive_on_odd_lengths() {
    for n in [0usize, 1, 3, 4, 5, 7, 8, 17] {
        let a: Vec<f64> = (0..n).map(|i| i as f64 * 0.5 - 1.0).collect();
        let b: Vec<f64> = (0..n).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_close(dot(&a, &b), naive, 1e-12);
    }
}

#[test]
fn axpy_scal_nrm() {
    let x = vec![3.0, 4.0];
    assert_close(nrm2(&x), 5.0, 1e-15);
    assert_close(nrm2_sq(&x), 25.0, 1e-15);
    let mut y = vec![1.0, 1.0];
    axpy(2.0, &x, &mut y);
    assert_eq!(y, vec![7.0, 9.0]);
    scal(0.5, &mut y);
    assert_eq!(y, vec![3.5, 4.5]);
    assert_eq!(nrm_inf(&[-7.0, 2.0]), 7.0);
}

#[test]
fn pos_neg_norms_partition_energy() {
    let x = vec![1.0, -2.0, 0.0, 3.0, -4.0];
    let p = nrm2_pos(&x);
    let n = nrm2_neg(&x);
    assert_close(p * p + n * n, nrm2_sq(&x), 1e-12);
    assert_close(p, (1.0f64 + 9.0).sqrt(), 1e-12);
    assert_close(n, (4.0f64 + 16.0).sqrt(), 1e-12);
}

#[test]
fn grouped_norms_respect_offsets() {
    let x = vec![3.0, 4.0, -5.0, 12.0, 0.0];
    let offsets = vec![0, 2, 5];
    let g = grouped_nrm2(&x, &offsets);
    assert_close(g[0], 5.0, 1e-12);
    assert_close(g[1], 13.0, 1e-12);
    let gp = grouped_nrm2_pos(&x, &offsets);
    assert_close(gp[1], 12.0, 1e-12);
    let gn = grouped_nrm2_neg(&x, &offsets);
    assert_close(gn[0], 0.0, 1e-12);
    assert_close(gn[1], 5.0, 1e-12);
}

#[test]
#[should_panic]
fn grouped_norms_bad_offsets_panics() {
    grouped_nrm2(&[1.0, 2.0], &[0, 1]);
}

#[test]
fn sq_euclidean_matches_direct() {
    let xs = Mat::from_vec(2, 2, vec![0., 0., 1., 2.]);
    let xt = Mat::from_vec(3, 2, vec![0., 0., 3., 4., -1., 0.]);
    let c = sq_euclidean_cost(&xs, &xt);
    assert_eq!(c.shape(), (2, 3));
    assert_close(c[(0, 0)], 0.0, 1e-12);
    assert_close(c[(0, 1)], 25.0, 1e-12);
    assert_close(c[(0, 2)], 1.0, 1e-12);
    assert_close(c[(1, 1)], 8.0, 1e-12);
}

#[test]
fn normalize_by_max_scales() {
    let mut c = Mat::from_vec(2, 2, vec![1., 2., 4., 0.5]);
    let m = normalize_by_max(&mut c);
    assert_eq!(m, 4.0);
    assert_close(c.max_abs(), 1.0, 1e-15);
}

#[test]
fn logsumexp_stable() {
    assert_close(logsumexp(&[0.0, 0.0]), 2.0f64.ln(), 1e-12);
    // Huge magnitudes must not overflow.
    let v = logsumexp(&[1000.0, 1000.0]);
    assert_close(v, 1000.0 + 2.0f64.ln(), 1e-9);
    assert_eq!(logsumexp(&[f64::NEG_INFINITY]), f64::NEG_INFINITY);
}

#[test]
fn frobenius_dot() {
    let a = Mat::from_vec(2, 2, vec![1., 2., 3., 4.]);
    let b = Mat::from_vec(2, 2, vec![5., 6., 7., 8.]);
    assert_close(a.frobenius_dot(&b), 70.0, 1e-12);
}
