//! Chaos suite: deterministic fault injection against the full serving
//! stack. Every test serializes on `FAULT_LOCK` — the failpoint
//! registry is process-global, and a fault armed by one test must never
//! leak into another's engine.
//!
//! The invariant under test is always the same: whatever is injected —
//! panics, delays, structured errors, hostile wire input, shutdown
//! mid-solve — every submitted request receives exactly one structured
//! response and the engine keeps serving afterwards.

use grpot::coordinator::config::{DatasetSpec, Method, SweepConfig};
use grpot::coordinator::metrics::Metrics;
use grpot::coordinator::service::{serve_with, Client};
use grpot::coordinator::{registry, sweep};
use grpot::fault::{self, sites, Action, CancelToken};
use grpot::jsonlite::Value;
use grpot::ot::regularizer::RegKind;
use grpot::ot::solve::SolveOptions;
use grpot::serve::{Engine, RejectReason, ServeConfig, SolveRequest};
use grpot::solvers::lbfgs::LbfgsOptions;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Hold the global fault lock for one test and guarantee the registry
/// is empty again when the test ends, pass or fail.
struct FaultGuard(#[allow(dead_code)] MutexGuard<'static, ()>);

impl Drop for FaultGuard {
    fn drop(&mut self) {
        fault::clear();
    }
}

fn arm(specs: &[(&str, Action, u64)]) -> FaultGuard {
    let guard = FAULT_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    let owned: Vec<(String, Action, u64)> =
        specs.iter().map(|(s, a, n)| (s.to_string(), *a, *n)).collect();
    fault::set_faults(&owned);
    FaultGuard(guard)
}

fn tiny_spec(seed: u64) -> DatasetSpec {
    DatasetSpec {
        family: "synthetic".into(),
        param1: 4,
        param2: 5,
        seed,
        ..Default::default()
    }
}

fn request(seed: u64, gamma: f64, rho: f64) -> SolveRequest {
    SolveRequest {
        spec: tiny_spec(seed),
        gamma,
        rho,
        method: Method::Fast,
        regularizer: RegKind::GroupLasso,
        deadline: None,
        warm_start: true,
    }
}

fn engine(cfg: ServeConfig) -> (Engine, Arc<Metrics>) {
    let metrics = Arc::new(Metrics::new());
    let engine = Engine::start(cfg, Arc::clone(&metrics));
    (engine, metrics)
}

/// A deadline long enough to survive admission and dequeue triage but
/// short against a solver whose every oracle evaluation sleeps 100 ms
/// must cancel *mid-solve*: the request ends with a structured
/// `DeadlineExceeded`, the mid-solve counter fires, and — the warm-start
/// regression this PR fixes — the cancelled iterate never seeds the
/// dual cache.
#[test]
fn midsolve_deadline_cancels_solve_and_skips_warm_cache() {
    let _g = arm(&[(sites::ORACLE_EVAL, Action::Delay(100), 1)]);
    let (engine, metrics) = engine(ServeConfig {
        workers: 1,
        solve: SolveOptions::new()
            .lbfgs(LbfgsOptions { max_iters: 4000, ftol: 1e-13, gtol: 1e-8, ..Default::default() }),
        ..Default::default()
    });

    let mut doomed = request(5, 0.8, 0.5);
    doomed.deadline = Some(Duration::from_millis(150));
    match engine.submit(doomed) {
        Err(RejectReason::DeadlineExceeded { waited_s }) => {
            assert!(waited_s > 0.0, "waited_s must be populated: {waited_s}");
        }
        other => panic!("expected mid-solve deadline expiry, got {:?}", other.map(|_| "ok")),
    }
    assert!(
        metrics.get("serve.cancelled_midsolve") >= 1,
        "the solve must stop at a cancellation checkpoint, not at triage"
    );

    // With the delay gone, the same key solves cold: the cancelled
    // iterate must NOT have been cached (it never converged).
    fault::clear();
    let cold = engine.submit(request(5, 0.8, 0.5)).expect("post-chaos solve");
    assert!(
        !cold.warm_started,
        "cancelled solve leaked a partial iterate into the warm-start cache"
    );
    // Sanity: the cache itself works — the next identical solve is warm.
    let warm = engine.submit(request(5, 0.8, 0.5)).expect("warm solve");
    assert!(warm.warm_started);
    engine.shutdown();
}

/// Shutdown under load: one slow worker, several queued clients. Every
/// submitter gets an answer — the in-flight solve stops at its next
/// cancellation checkpoint, queued tickets fast-drain — and nobody
/// hangs (the `thread::scope` join IS the assertion).
#[test]
fn shutdown_under_load_answers_every_ticket() {
    let _g = arm(&[(sites::ORACLE_EVAL, Action::Delay(30), 1)]);
    let (engine, metrics) = engine(ServeConfig {
        workers: 1,
        queue_capacity: 64,
        ..Default::default()
    });
    let clients = 5;
    let answered = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for c in 0..clients {
            let engine = &engine;
            let answered = &answered;
            s.spawn(move || {
                // Distinct γ per client so the batcher can't collapse
                // the queue into one job.
                match engine.submit(request(13, 0.2 + 0.1 * c as f64, 0.5)) {
                    Ok(_) | Err(RejectReason::Shutdown) => {
                        answered.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                    }
                    Err(other) => panic!("unexpected rejection during shutdown: {other}"),
                }
            });
        }
        // Let the first job get mid-solve and the rest queue up.
        std::thread::sleep(Duration::from_millis(60));
        engine.shutdown();
    });
    assert_eq!(answered.load(std::sync::atomic::Ordering::SeqCst), clients);
    assert!(
        metrics.get("serve.cancelled_midsolve") >= 1,
        "shutdown must cancel the in-flight solve, not wait it out"
    );
}

/// A solver that panics on every third solve degrades single requests,
/// never the engine: panicked solves answer with structured errors,
/// interleaved successes keep the dataset's breaker closed, and the
/// worker pool keeps serving.
#[test]
fn periodic_solver_panics_degrade_requests_not_the_engine() {
    let _g = arm(&[(sites::ENGINE_SOLVE, Action::Panic, 3)]);
    let (engine, metrics) = engine(ServeConfig { workers: 1, ..Default::default() });
    let mut outcomes = Vec::new();
    for k in 0..9 {
        outcomes.push(engine.submit(request(29, 0.2 + 0.1 * k as f64, 0.5)));
    }
    for (k, out) in outcomes.iter().enumerate() {
        if (k + 1) % 3 == 0 {
            match out {
                Err(RejectReason::Failed(e)) => {
                    assert!(e.to_string().contains("panicked"), "unexpected error: {e}");
                }
                _ => panic!("solve {} should have hit the panic failpoint", k + 1),
            }
        } else {
            assert!(out.is_ok(), "solve {} should have succeeded", k + 1);
        }
    }
    assert_eq!(metrics.get("serve.solve_panics"), 3);
    // Non-consecutive failures never quarantine the key.
    assert_eq!(metrics.get("serve.rejected_quarantined"), 0);
    engine.shutdown();
}

/// An always-failing dataset build trips the per-key circuit breaker:
/// after the threshold, requests fast-fail with `Quarantined` instead of
/// burning a worker, and once the fault is gone a half-open probe closes
/// the breaker again.
#[test]
fn breaker_quarantines_poisoned_dataset_then_recovers() {
    let _g = arm(&[(sites::ENGINE_DATASET_BUILD, Action::Err, 1)]);
    let (engine, metrics) = engine(ServeConfig {
        workers: 1,
        breaker_threshold: 2,
        breaker_cooldown: Duration::from_millis(100),
        ..Default::default()
    });
    // Two consecutive build failures reach the threshold…
    for k in 0..2 {
        match engine.submit(request(37, 0.3 + 0.1 * k as f64, 0.5)) {
            Err(RejectReason::Failed(_)) => {}
            other => panic!("build failpoint should fail request {k}: {:?}", other.map(|_| "ok")),
        }
    }
    assert_eq!(metrics.get("serve.breaker_trips"), 1);
    // …and the third request is rejected at admission without a build.
    match engine.submit(request(37, 0.9, 0.5)) {
        Err(RejectReason::Quarantined { retry_in_s }) => assert!(retry_in_s >= 0.0),
        other => panic!("expected quarantine: {:?}", other.map(|_| "ok")),
    }
    assert!(metrics.get("serve.rejected_quarantined") >= 1);

    // Heal the dataset, wait out the cooldown: the next request is the
    // half-open probe, succeeds, and closes the breaker for good.
    fault::clear();
    std::thread::sleep(Duration::from_millis(150));
    engine.submit(request(37, 0.9, 0.5)).expect("half-open probe must be admitted");
    engine.submit(request(37, 1.1, 0.5)).expect("breaker must be closed after the probe");
    engine.shutdown();
}

/// With history showing ~300 ms solves and a worker already busy, a
/// request with a millisecond deadline is shed at admission — a
/// structured `Overloaded`, not a queued ticket doomed to expire.
#[test]
fn overload_sheds_requests_that_cannot_meet_their_deadline() {
    let _g = arm(&[(sites::ORACLE_EVAL, Action::Delay(50), 1)]);
    let (engine, metrics) = engine(ServeConfig {
        workers: 1,
        // Cap iterations so delayed solves finish in ~300 ms and the
        // solve-time histogram gets a real observation.
        solve: SolveOptions::new()
            .lbfgs(LbfgsOptions { max_iters: 6, ..Default::default() }),
        ..Default::default()
    });
    // Seed the histogram: one completed (capped) solve.
    engine.submit(request(43, 0.3, 0.5)).expect("seed solve");

    std::thread::scope(|s| {
        let a = s.spawn(|| engine.submit(request(43, 0.5, 0.5)));
        // Wait until A is in the worker, then queue B behind it.
        std::thread::sleep(Duration::from_millis(30));
        let b = s.spawn(|| engine.submit(request(43, 0.7, 0.5)));
        let t0 = Instant::now();
        while engine.queue_depth() == 0 && t0.elapsed() < Duration::from_secs(2) {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert!(engine.queue_depth() >= 1, "ticket B never queued");

        // C cannot meet a 1 ms deadline behind a ~300 ms queue: shed.
        let mut c = request(43, 0.9, 0.5);
        c.deadline = Some(Duration::from_millis(1));
        match engine.submit(c) {
            Err(RejectReason::Overloaded { estimated_wait_s }) => {
                assert!(estimated_wait_s > 0.001, "estimate too small: {estimated_wait_s}");
            }
            other => panic!("expected load shed: {:?}", other.map(|_| "ok")),
        }
        a.join().unwrap().expect("A must complete");
        b.join().unwrap().expect("B must complete");
    });
    assert!(metrics.get("serve.rejected_overloaded") >= 1);
    engine.shutdown();
}

/// Survivability sweep: every registered failpoint site × every action,
/// firing on every hit. Whatever fires, each submit produces exactly one
/// outcome (a reply, a structured rejection, or — only at the admission
/// site — a propagated panic, which is that site's documented contract)
/// and the engine shuts down cleanly afterwards.
#[test]
fn every_site_and_action_leaves_the_engine_answering() {
    let guard = FAULT_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    let _cleanup = FaultGuard(guard);
    for site in sites::ALL {
        for action in [Action::Panic, Action::Err, Action::Delay(1)] {
            fault::set_faults(&[(site.to_string(), action, 1)]);
            let (engine, _metrics) = engine(ServeConfig { workers: 1, ..Default::default() });
            for k in 0..2u64 {
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    engine.submit(request(100 + k, 0.4 + 0.2 * k as f64, 0.5))
                }));
                match out {
                    Ok(_reply_or_reject) => {}
                    Err(_) => assert!(
                        site == sites::QUEUE_ADMIT && action == Action::Panic,
                        "only queue.admit:panic may unwind into the submitter \
                         (got a panic from {site}:{action:?})"
                    ),
                }
            }
            fault::clear();
            // Post-chaos: the same engine must still serve.
            engine
                .submit(request(200, 1.0, 0.5))
                .unwrap_or_else(|e| panic!("engine dead after {site}:{action:?}: {e}"));
            engine.shutdown();
        }
    }
}

/// Sub-eval cancellation checkpoint: a token cancelled before the solve
/// starts must stop it inside the *first* oracle evaluation's column
/// chunks (one relaxed load per chunk), surfacing `Cancelled` after
/// zero completed iterations — while an armed-but-never-fired token
/// leaves every byte of the result untouched.
#[test]
fn sub_eval_cancellation_stops_first_eval_and_armed_token_is_byte_neutral() {
    let _g = arm(&[]); // no faults; lock still serializes the suite
    let pair = registry::build_pair(&tiny_spec(61)).expect("pair");
    let prob = grpot::ot::dual::OtProblem::from_dataset(&pair);
    let base = SolveOptions::new().gamma(0.7).rho(0.5).max_iters(200);

    // Pre-cancelled: the per-chunk poll inside eval sees it immediately.
    let dead = CancelToken::new();
    dead.cancel();
    let cancelled = grpot::ot::fastot::solve(&prob, &base.clone().cancel(dead))
        .expect("cancellation is a stop reason, not an error");
    assert_eq!(cancelled.stop, grpot::solvers::StopReason::Cancelled);
    assert_eq!(cancelled.iterations, 0, "no iteration may complete after cancel");

    // Armed but never fired: byte-identical to running with no token,
    // across both oracle families (screened fast + dense origin).
    let far = std::time::Instant::now() + Duration::from_secs(3600);
    for method in [Method::Fast, Method::Origin] {
        let plain = sweep::solve(&prob, method, &base).expect("plain solve");
        let armed_opts = base.clone().cancel(CancelToken::with_deadline(far));
        let armed = sweep::solve(&prob, method, &armed_opts).expect("armed solve");
        assert_eq!(plain.dual_objective.to_bits(), armed.dual_objective.to_bits());
        assert_eq!(plain.iterations, armed.iterations);
        assert_eq!(plain.x.len(), armed.x.len());
        for (a, b) in plain.x.iter().zip(&armed.x) {
            assert_eq!(a.to_bits(), b.to_bits(), "{} iterate drifted", method.name());
        }
    }
}

/// The `sweep.job` failpoint makes the sweep coordinator surface a
/// structured error — the grid stops cleanly in both the serial and the
/// threaded scheduler instead of killing a worker or hanging the pool.
#[test]
fn sweep_job_failpoint_surfaces_structured_error() {
    let _g = arm(&[(sites::SWEEP_JOB, Action::Err, 1)]);
    let cfg = SweepConfig {
        dataset: tiny_spec(67),
        gammas: vec![0.5, 1.0],
        rhos: vec![0.5],
        methods: vec![Method::Fast],
        threads: 1,
        solve: SolveOptions::new().max_iters(50).regularizer(RegKind::GroupLasso),
    };
    let metrics = Metrics::new();
    let err = sweep::run_sweep(&cfg, &metrics).expect_err("failpoint must surface");
    assert!(err.to_string().contains("sweep.job"), "unexpected error: {err}");
    let threaded = SweepConfig { threads: 2, ..cfg.clone() };
    let err = sweep::run_sweep(&threaded, &metrics).expect_err("threaded failpoint");
    assert!(err.to_string().contains("sweep.job"), "unexpected error: {err}");

    // Registry healed: the identical grid runs to completion.
    fault::clear();
    let report = sweep::run_sweep(&cfg, &metrics).expect("post-chaos sweep");
    assert_eq!(report.records.len(), 2);
}

/// Wire-level chaos: garbage bytes, malformed/hostile fields, and
/// mid-stream disconnects must each produce a structured error (or a
/// dropped connection) without taking the service down.
#[test]
fn wire_protocol_survives_garbage_and_hostile_requests() {
    let _g = arm(&[]); // no faults; lock still serializes the suite
    let handle = serve_with(
        "127.0.0.1:0",
        ServeConfig { workers: 1, ..Default::default() },
    )
    .expect("bind");

    // Raw garbage on the socket: the connection may answer with an
    // error object or drop — either way the listener survives.
    {
        let mut raw = TcpStream::connect(handle.addr).expect("connect raw");
        raw.write_all(b"this is not json\n").expect("write garbage");
        let mut line = String::new();
        let _ = BufReader::new(raw).read_line(&mut line);
        if !line.is_empty() {
            let v = grpot::jsonlite::parse(line.trim()).expect("error reply must be JSON");
            assert_eq!(v.get("ok").and_then(Value::as_bool), Some(false), "{v}");
        }
    }
    // Mid-stream disconnect: a half-written request then a vanishing
    // client must not wedge the per-connection reader.
    {
        let mut raw = TcpStream::connect(handle.addr).expect("connect raw");
        raw.write_all(b"{\"op\":").expect("write partial");
    }

    let mut c = Client::connect(&handle.addr).expect("connect client");
    let base = || {
        Value::obj()
            .set("op", "solve")
            .set(
                "dataset",
                Value::obj()
                    .set("family", "synthetic")
                    .set("param1", 4usize)
                    .set("param2", 5usize)
                    .set("seed", 51usize),
            )
            .set("gamma", 0.5)
            .set("rho", 0.5)
            .set("method", "fast")
    };
    let expect_rejected = |c: &mut Client, req: Value, what: &str| {
        let resp = c.call(&req).unwrap_or_else(|e| panic!("{what}: transport died: {e}"));
        assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(false), "{what}: {resp}");
        assert!(resp.get("error").is_some(), "{what}: missing error field: {resp}");
    };
    expect_rejected(&mut c, base().set("regularizer", "bogus"), "unknown regularizer");
    expect_rejected(
        &mut c,
        base().set(
            "dataset",
            Value::obj().set("family", "synthetic").set("param1", 10_000_000usize),
        ),
        "oversized dataset params",
    );
    expect_rejected(
        &mut c,
        base().set(
            "dataset",
            Value::obj().set("family", "faces").set("scale", -1.0),
        ),
        "negative dataset scale",
    );
    expect_rejected(
        &mut c,
        base().set(
            "dataset",
            Value::obj().set("family", "synthetic").set("seed", -3.0),
        ),
        "negative dataset seed",
    );
    // After all of it, an honest request still solves.
    let good = c.call(&base()).expect("solve");
    assert_eq!(good.get("ok").and_then(Value::as_bool), Some(true), "{good}");
    handle.shutdown();
}
