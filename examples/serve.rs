//! Serving demo: start the TCP OT service, fire concurrent solve
//! requests from client threads, and report latency / throughput — the
//! "OT-as-a-service" deployment shape, with Python nowhere on the
//! request path.
//!
//! Run: `cargo run --release --example serve`

use grpot::benchlib::Summary;
use grpot::coordinator::service::{serve, Client};
use grpot::error::Result;
use grpot::jsonlite::Value;
use std::sync::{Arc, Mutex};
use std::time::Instant;

fn main() -> Result<()> {
    let handle = serve("127.0.0.1:0", 4)?;
    let addr = handle.addr;
    println!("service up on {addr}");

    // Warm the dataset cache with one request.
    let mut warm = Client::connect(&addr)?;
    assert!(warm.ping()?);
    let req = |gamma: f64, rho: f64| {
        Value::obj()
            .set("op", "solve")
            .set(
                "dataset",
                Value::obj()
                    .set("family", "synthetic")
                    .set("param1", 10usize)
                    .set("param2", 10usize)
                    .set("seed", 7usize),
            )
            .set("gamma", gamma)
            .set("rho", rho)
            .set("method", "fast")
    };
    let first = warm.call(&req(0.1, 0.6))?;
    assert!(
        first.get("ok").and_then(Value::as_bool) == Some(true),
        "warmup failed: {first}"
    );
    println!(
        "warmup solve: dual={:.6} acc={:.3}",
        first.get("dual_objective").and_then(Value::as_f64).unwrap(),
        first.get("otda_accuracy").and_then(Value::as_f64).unwrap()
    );

    // Concurrent clients sweeping (γ, ρ) pairs.
    let clients = 4;
    let per_client = 6;
    let latencies = Arc::new(Mutex::new(Vec::new()));
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for c in 0..clients {
            let latencies = Arc::clone(&latencies);
            let req = &req;
            s.spawn(move || {
                let mut client = Client::connect(&addr).expect("connect");
                for k in 0..per_client {
                    let gamma = [0.05, 0.1, 0.5][(c + k) % 3];
                    let rho = [0.4, 0.6, 0.8][(c * 2 + k) % 3];
                    let t = Instant::now();
                    let resp = client.call(&req(gamma, rho)).expect("call");
                    let dt = t.elapsed().as_secs_f64();
                    assert_eq!(resp.get("ok").and_then(Value::as_bool), Some(true), "{resp}");
                    latencies.lock().unwrap().push(dt);
                }
            });
        }
    });
    let total = t0.elapsed().as_secs_f64();
    let lats = latencies.lock().unwrap().clone();
    let s = Summary::from_samples(&lats);
    println!("\n== serving stats ({} requests, {clients} concurrent clients) ==", lats.len());
    println!("throughput : {:.2} req/s", lats.len() as f64 / total);
    println!(
        "latency    : median {:.1} ms | p90 {:.1} ms | max {:.1} ms",
        s.median * 1e3,
        s.p90 * 1e3,
        s.max * 1e3
    );

    // Metrics from the server itself. The serving engine batches
    // same-dataset requests, so the problem cache is consulted once per
    // micro-batch (not per request) — but the cost matrix must still
    // have been generated exactly once.
    let metrics = warm.call(&Value::obj().set("op", "metrics"))?;
    let misses = metrics
        .get_path(&["metrics", "counters", "service.cache_misses"])
        .and_then(Value::as_usize)
        .unwrap_or(0);
    let warm_hits = metrics
        .get_path(&["metrics", "counters", "serve.warm_hits"])
        .and_then(Value::as_usize)
        .unwrap_or(0);
    let p99 = metrics
        .get_path(&["metrics", "hists", "serve.latency_seconds", "p99"])
        .and_then(Value::as_f64)
        .unwrap_or(0.0);
    println!("cost matrix: built {misses}x (cached after the first build)");
    println!("warm starts: {warm_hits} solves seeded from the dual cache");
    println!("engine p99 : {:.1} ms", p99 * 1e3);
    assert_eq!(misses, 1);

    handle.shutdown();
    println!("\nserve OK");
    Ok(())
}
