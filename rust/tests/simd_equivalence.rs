//! Scalar/SIMD byte-equality, end to end: for a fixed problem and
//! config, `SimdMode::Scalar` (the reference kernels) and
//! `SimdMode::Auto` (runtime-dispatched vector kernels — AVX2 where the
//! CPU has it, the portable lane mirror elsewhere) must return
//! *byte-equal* solutions, objectives, iteration counts and
//! `OracleStats`, for the screened, dense and semi-dual methods, cold
//! and warm-started, at 1 and 4 oracle threads. The `GRPOT_SIMD=scalar`
//! CI shard re-runs the theorem2 suite (and this one) with the env
//! override, so both dispatch paths are gated on every push.
//!
//! Note on the env override: `GRPOT_SIMD`, when set, replaces only the
//! default `Auto` policy (explicitly forced modes win) — under the
//! scalar CI shard the `Auto` sides of these comparisons resolve to
//! the scalar backend, so the scalar-vs-auto assertions hold trivially
//! there while the portable-vs-auto test becomes a genuine
//! portable-vs-scalar cross; the full dispatch-crossing coverage comes
//! from the default (env-less) run.

use grpot::linalg::Mat;
use grpot::ot::dual::{OracleStats, OtProblem};
use grpot::ot::fastot::{solve_fast_ot, solve_fast_ot_from, FastOtConfig, FastOtResult};
use grpot::ot::origin::{solve_origin, solve_origin_from};
use grpot::ot::semidual::solve_semidual_simd;
use grpot::rng::Pcg64;
use grpot::simd::{Dispatch, SimdMode};
use grpot::solvers::lbfgs::LbfgsOptions;

fn random_problem(seed: u64, l: usize, g: usize, n: usize) -> OtProblem {
    let mut rng = Pcg64::new(seed);
    let m = l * g;
    let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
    let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
    OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
}

fn assert_stats_eq(a: &OracleStats, b: &OracleStats, what: &str) {
    assert_eq!(a.evals, b.evals, "{what}: evals");
    assert_eq!(a.grads_computed, b.grads_computed, "{what}: grads_computed");
    assert_eq!(a.grads_skipped, b.grads_skipped, "{what}: grads_skipped");
    assert_eq!(a.ub_checks, b.ub_checks, "{what}: ub_checks");
    assert_eq!(a.ws_hits, b.ws_hits, "{what}: ws_hits");
    assert_eq!(a.per_eval_grads, b.per_eval_grads, "{what}: per_eval_grads");
}

fn assert_results_identical(a: &FastOtResult, b: &FastOtResult, what: &str) {
    assert_eq!(a.x, b.x, "{what}: solution bytes");
    assert_eq!(a.dual_objective, b.dual_objective, "{what}: objective");
    assert_eq!(a.iterations, b.iterations, "{what}: iterations");
    assert_eq!(a.outer_rounds, b.outer_rounds, "{what}: outer rounds");
    assert_stats_eq(&a.stats, &b.stats, what);
}

fn cfg(gamma: f64, rho: f64, threads: usize, simd: SimdMode) -> FastOtConfig {
    FastOtConfig {
        gamma,
        rho,
        threads,
        simd,
        lbfgs: LbfgsOptions { max_iters: 120, ..Default::default() },
        ..Default::default()
    }
}

/// The acceptance-criterion test: scalar vs auto dispatch are byte-equal
/// for `solve_fast_ot` and `solve_origin` across hyperparameters hitting
/// both the skip-heavy and the dense regime, at 1 and 4 threads, cold
/// start.
#[test]
fn fast_and_origin_bit_identical_across_dispatch() {
    // n = 37: multiple fixed chunks, ragged panels, a short final chunk.
    let prob = random_problem(0x51D0, 5, 4, 37);
    for (gamma, rho) in [(0.1, 0.3), (1.0, 0.5), (8.0, 0.8)] {
        for threads in [1usize, 4] {
            let fast_s = solve_fast_ot(&prob, &cfg(gamma, rho, threads, SimdMode::Scalar));
            let fast_a = solve_fast_ot(&prob, &cfg(gamma, rho, threads, SimdMode::Auto));
            assert_results_identical(
                &fast_s,
                &fast_a,
                &format!("fast γ={gamma} ρ={rho} threads={threads}"),
            );
            let orig_s = solve_origin(&prob, &cfg(gamma, rho, threads, SimdMode::Scalar));
            let orig_a = solve_origin(&prob, &cfg(gamma, rho, threads, SimdMode::Auto));
            assert_results_identical(
                &orig_s,
                &orig_a,
                &format!("origin γ={gamma} ρ={rho} threads={threads}"),
            );
            // Theorem 2 must keep holding across methods under either
            // dispatch.
            assert_eq!(fast_a.dual_objective, orig_a.dual_objective);
            assert_eq!(fast_a.x, orig_a.x);
        }
    }
}

/// Warm starts compose with dispatch: scalar and auto solves seeded at
/// the same arbitrary iterate stay byte-equal (snapshots start at the
/// warm point, so the screened walk immediately exercises the
/// mixed-activity fallback lanes).
#[test]
fn warm_started_solves_bit_identical_across_dispatch() {
    let prob = random_problem(0x51D1, 4, 3, 33);
    let mut rng = Pcg64::new(99);
    let x0: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.2, 0.3)).collect();
    for threads in [1usize, 4] {
        let fast_s =
            solve_fast_ot_from(&prob, &cfg(0.6, 0.55, threads, SimdMode::Scalar), x0.clone());
        let fast_a =
            solve_fast_ot_from(&prob, &cfg(0.6, 0.55, threads, SimdMode::Auto), x0.clone());
        assert_results_identical(&fast_s, &fast_a, &format!("warm fast threads={threads}"));
        let orig_s =
            solve_origin_from(&prob, &cfg(0.6, 0.55, threads, SimdMode::Scalar), x0.clone());
        let orig_a =
            solve_origin_from(&prob, &cfg(0.6, 0.55, threads, SimdMode::Auto), x0.clone());
        assert_results_identical(&orig_s, &orig_a, &format!("warm origin threads={threads}"));
    }
}

/// The working-set path (ℕ members bypassing the bound check) must also
/// be dispatch-invariant — covered by solving with and without ℕ.
#[test]
fn working_set_toggle_is_dispatch_invariant() {
    let prob = random_problem(0x51D2, 4, 4, 29);
    for use_ws in [false, true] {
        let mk = |simd| FastOtConfig { use_working_set: use_ws, ..cfg(0.4, 0.6, 1, simd) };
        let s = solve_fast_ot(&prob, &mk(SimdMode::Scalar));
        let a = solve_fast_ot(&prob, &mk(SimdMode::Auto));
        assert_results_identical(&s, &a, &format!("fast use_ws={use_ws}"));
    }
}

/// The portable lane mirror must agree with whatever `Auto` resolves to
/// — on AVX2 hardware this crosses the intrinsics against the mirror;
/// elsewhere both resolve to the mirror and the test is a no-op check.
#[test]
fn portable_mirror_matches_auto_dispatch() {
    let prob = random_problem(0x51D3, 3, 5, 23);
    for (gamma, rho) in [(0.5, 0.5), (5.0, 0.8)] {
        let p = solve_fast_ot(&prob, &cfg(gamma, rho, 1, SimdMode::Portable));
        let a = solve_fast_ot(&prob, &cfg(gamma, rho, 1, SimdMode::Auto));
        assert_results_identical(&p, &a, &format!("portable-vs-auto γ={gamma} ρ={rho}"));
    }
}

/// Semi-dual: the SIMD column staging is element-wise, so scalar and
/// auto dispatch must be byte-equal end to end (alpha, objective,
/// iterations, plan), at 1 and 4 threads.
#[test]
fn semidual_bit_identical_across_dispatch() {
    let prob = random_problem(0x51D4, 3, 4, 41);
    let opts = LbfgsOptions { max_iters: 200, ..Default::default() };
    for threads in [1usize, 4] {
        let s = solve_semidual_simd(&prob, 0.2, &opts, threads, SimdMode::Scalar);
        let a = solve_semidual_simd(&prob, 0.2, &opts, threads, SimdMode::Auto);
        assert_eq!(s.alpha, a.alpha, "threads={threads}: alpha bytes");
        assert_eq!(s.objective, a.objective, "threads={threads}: objective");
        assert_eq!(s.iterations, a.iterations, "threads={threads}: iterations");
        assert_eq!(s.plan, a.plan, "threads={threads}: plan");
    }
}

/// Sanity: when no env override is active, `Auto` really does resolve
/// to a vector backend, so the equivalence tests above crossed two
/// genuinely different code paths.
#[test]
fn auto_dispatch_is_vector_without_env_override() {
    if std::env::var("GRPOT_SIMD").is_err() {
        assert!(Dispatch::resolve(SimdMode::Auto).is_vector());
    }
}
