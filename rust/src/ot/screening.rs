//! Safe screening for the group-sparse OT dual — the paper's
//! contribution.
//!
//! Two devices accelerate the `O(|L|·n·g)` gradient evaluation:
//!
//! 1. **Upper bound** (Definition 1, Lemma 1–3). With snapshots
//!    `(α̃, β̃, Z̃)` taken every `r` solver iterations,
//!    `z̄_{l,j} = z̃_{l,j} + ‖[Δα_[l]]₊‖₂ + √g_l·[Δβ_j]₊ ≥ z_{l,j}`,
//!    so `z̄_{l,j} ≤ τ` proves `∇ψ(·)_[l] = 0` and the `O(g)` group
//!    computation is skipped — at `O(1)` marginal cost per pair once the
//!    `O(m+n)` per-eval Δ-norms are in place.
//! 2. **Lower bound / working set ℕ** (Definitions 2–3, Lemma 4–6).
//!    `z̲_{l,j} ≤ z_{l,j}`, so `z̲_{l,j} > τ` proves the group is
//!    *non*-zero; such pairs enter ℕ and bypass the upper-bound check,
//!    removing its overhead where it cannot help.
//!
//! Both devices are *safe*: every non-skipped pair is computed by the
//! exact same kernel as the dense baseline
//! ([`crate::ot::dual::group_grad_contrib`]), so the optimization
//! trajectory is identical (Theorem 2).

use super::dual::{exact_z, group_grad_contrib, DualOracle, DualParams, OracleStats, OtProblem};
use crate::linalg;

/// Screening-specific counters are kept in [`OracleStats`]; this struct
/// adds the Fig.-B diagnostic output.
#[derive(Clone, Debug, Default)]
pub struct BoundErrors {
    /// Mean `|z̄ − z|` over all (l, j).
    pub mean_upper: f64,
    /// Max `|z̄ − z|`.
    pub max_upper: f64,
    /// Mean `|z − z̲|` (working-set construction error).
    pub mean_lower: f64,
    /// Max `|z − z̲|`.
    pub max_lower: f64,
}

/// The screened negated-dual oracle (Algorithm 2).
pub struct ScreeningOracle<'a> {
    prob: &'a OtProblem,
    params: DualParams,
    tau: f64,
    lq: f64,
    use_ws: bool,
    // Snapshot state (Definitions 1–2), refreshed by `refresh`.
    snap_alpha: Vec<f64>,
    snap_beta: Vec<f64>,
    /// `z̃_{l,j}` at index `j·|L| + l` (column-major in l for per-column walks).
    snap_z: Vec<f64>,
    /// `k̃_{l,j} = ‖f̃_[l]‖₂` (only when the working set is enabled).
    snap_k: Vec<f64>,
    /// `õ_{l,j} = ‖[f̃_[l]]₋‖₂` (only when the working set is enabled).
    snap_o: Vec<f64>,
    /// Working set ℕ as a dense boolean mask, same indexing as `snap_z`.
    ws: Vec<bool>,
    // Per-eval scratch (allocated once).
    da_pos: Vec<f64>,
    grad_scratch: Vec<f64>,
    stats: OracleStats,
}

impl<'a> ScreeningOracle<'a> {
    /// Create with snapshots initialized at `x = 0` and ℕ = ∅
    /// (Algorithm 1, line 1).
    pub fn new(prob: &'a OtProblem, params: DualParams, use_working_set: bool) -> Self {
        params.validate();
        let m = prob.m();
        let n = prob.n();
        let num_groups = prob.groups.num_groups();
        let mut o = ScreeningOracle {
            prob,
            tau: params.tau(),
            lq: params.lambda_quad(),
            params,
            use_ws: use_working_set,
            snap_alpha: vec![0.0; m],
            snap_beta: vec![0.0; n],
            snap_z: vec![0.0; n * num_groups],
            snap_k: if use_working_set { vec![0.0; n * num_groups] } else { vec![] },
            snap_o: if use_working_set { vec![0.0; n * num_groups] } else { vec![] },
            ws: vec![false; n * num_groups],
            da_pos: vec![0.0; num_groups],
            grad_scratch: vec![0.0; prob.groups.max_size()],
            stats: OracleStats::default(),
        };
        o.recompute_snapshots();
        o
    }

    pub fn params(&self) -> &DualParams {
        &self.params
    }

    /// Fraction of (l, j) pairs currently in the working set.
    pub fn working_set_density(&self) -> f64 {
        if self.ws.is_empty() {
            return 0.0;
        }
        self.ws.iter().filter(|&&b| b).count() as f64 / self.ws.len() as f64
    }

    /// Dense snapshot recomputation: one `O(mn)` pass filling z̃ (and
    /// k̃/õ when the working set is on) at the *current snapshot point*.
    fn recompute_snapshots(&mut self) {
        let num_groups = self.prob.groups.num_groups();
        let n = self.prob.n();
        for j in 0..n {
            let c_j = self.prob.cost_t.row(j);
            let beta_j = self.snap_beta[j];
            let base = j * num_groups;
            for l in 0..num_groups {
                let mut zsq = 0.0;
                let mut ksq = 0.0;
                let mut osq = 0.0;
                for i in self.prob.groups.range(l) {
                    let f = self.snap_alpha[i] + beta_j - c_j[i];
                    ksq += f * f;
                    if f > 0.0 {
                        zsq += f * f;
                    } else {
                        osq += f * f;
                    }
                }
                self.snap_z[base + l] = zsq.sqrt();
                if self.use_ws {
                    self.snap_k[base + l] = ksq.sqrt();
                    self.snap_o[base + l] = osq.sqrt();
                }
            }
        }
    }

    /// Build ℕ from the *old* snapshots and the current iterate
    /// (Algorithm 1 lines 4–14), exactly in the paper's order — the set
    /// is constructed before the snapshots move.
    fn rebuild_working_set(&mut self, x: &[f64]) {
        let m = self.prob.m();
        let n = self.prob.n();
        let num_groups = self.prob.groups.num_groups();
        let (alpha, beta) = x.split_at(m);
        // Per-group ‖Δα_[l]‖₂ and ‖[Δα_[l]]₋‖₂.
        let mut da_nrm = vec![0.0; num_groups];
        let mut da_neg = vec![0.0; num_groups];
        for l in 0..num_groups {
            let mut s = 0.0;
            let mut sn = 0.0;
            for i in self.prob.groups.range(l) {
                let d = alpha[i] - self.snap_alpha[i];
                s += d * d;
                if d < 0.0 {
                    sn += d * d;
                }
            }
            da_nrm[l] = s.sqrt();
            da_neg[l] = sn.sqrt();
        }
        let sqrt_g = &self.prob.groups.sqrt_sizes;
        for j in 0..n {
            let db = beta[j] - self.snap_beta[j];
            let db_abs = db.abs();
            let db_neg = (-db).max(0.0);
            let base = j * num_groups;
            for l in 0..num_groups {
                // Eq. 7.
                let lower = self.snap_k[base + l]
                    - da_nrm[l]
                    - sqrt_g[l] * db_abs
                    - self.snap_o[base + l]
                    - da_neg[l]
                    - sqrt_g[l] * db_neg;
                self.ws[base + l] = lower > self.tau;
            }
        }
    }

    /// Fig.-B diagnostic: exact `z`, upper bound `z̄` and lower bound
    /// `z̲` for every pair at `x`, against the *current* snapshots.
    pub fn bound_errors(&self, x: &[f64]) -> BoundErrors {
        let m = self.prob.m();
        let n = self.prob.n();
        let num_groups = self.prob.groups.num_groups();
        let (alpha, beta) = x.split_at(m);
        let mut da_pos = vec![0.0; num_groups];
        let mut da_nrm = vec![0.0; num_groups];
        let mut da_neg = vec![0.0; num_groups];
        for l in 0..num_groups {
            let (mut sp, mut s, mut sn) = (0.0, 0.0, 0.0);
            for i in self.prob.groups.range(l) {
                let d = alpha[i] - self.snap_alpha[i];
                s += d * d;
                if d > 0.0 {
                    sp += d * d;
                } else {
                    sn += d * d;
                }
            }
            da_pos[l] = sp.sqrt();
            da_nrm[l] = s.sqrt();
            da_neg[l] = sn.sqrt();
        }
        let sqrt_g = &self.prob.groups.sqrt_sizes;
        let mut out = BoundErrors::default();
        let mut count = 0.0;
        for j in 0..n {
            let c_j = self.prob.cost_t.row(j);
            let beta_j = beta[j];
            let db = beta_j - self.snap_beta[j];
            let db_pos = db.max(0.0);
            let db_abs = db.abs();
            let db_neg = (-db).max(0.0);
            let base = j * num_groups;
            for l in 0..num_groups {
                let z = exact_z(alpha, beta_j, c_j, self.prob.groups.range(l));
                let ub = self.snap_z[base + l] + da_pos[l] + sqrt_g[l] * db_pos;
                out.mean_upper += ub - z;
                out.max_upper = out.max_upper.max(ub - z);
                if self.use_ws {
                    let lb = self.snap_k[base + l]
                        - da_nrm[l]
                        - sqrt_g[l] * db_abs
                        - self.snap_o[base + l]
                        - da_neg[l]
                        - sqrt_g[l] * db_neg;
                    out.mean_lower += z - lb;
                    out.max_lower = out.max_lower.max(z - lb);
                }
                count += 1.0;
            }
        }
        out.mean_upper /= count;
        out.mean_lower /= count;
        out
    }
}

impl DualOracle for ScreeningOracle<'_> {
    fn shape(&self) -> (usize, usize) {
        (self.prob.m(), self.prob.n())
    }

    fn eval(&mut self, x: &[f64], grad: &mut [f64]) -> f64 {
        let m = self.prob.m();
        let n = self.prob.n();
        let num_groups = self.prob.groups.num_groups();
        debug_assert_eq!(x.len(), m + n);
        let (alpha, beta) = x.split_at(m);

        // Per-eval precomputation (Algorithm 2, line 5): ‖[Δα_[l]]₊‖₂.
        for l in 0..num_groups {
            let mut sp = 0.0;
            for i in self.prob.groups.range(l) {
                let d = alpha[i] - self.snap_alpha[i];
                if d > 0.0 {
                    sp += d * d;
                }
            }
            self.da_pos[l] = sp.sqrt();
        }

        for (gi, &ai) in grad[..m].iter_mut().zip(&self.prob.a) {
            *gi = -ai;
        }
        for (gj, &bj) in grad[m..].iter_mut().zip(&self.prob.b) {
            *gj = -bj;
        }
        let (grad_alpha, grad_beta) = grad.split_at_mut(m);

        let tau = self.tau;
        let lq = self.lq;
        let sqrt_g = &self.prob.groups.sqrt_sizes;
        let mut psi_total = 0.0;
        let mut grads_this_eval = 0u64;

        for j in 0..n {
            let c_j = self.prob.cost_t.row(j);
            let beta_j = beta[j];
            let db_pos = (beta_j - self.snap_beta[j]).max(0.0);
            let base = j * num_groups;
            let mut col_mass = 0.0;
            for l in 0..num_groups {
                let compute = if self.use_ws && self.ws[base + l] {
                    // ℕ member: provably nonzero, no check (Alg. 2 lines 2–4).
                    self.stats.ws_hits += 1;
                    true
                } else {
                    // Upper bound check (Alg. 2 lines 6–13).
                    self.stats.ub_checks += 1;
                    let ub = self.snap_z[base + l] + self.da_pos[l] + sqrt_g[l] * db_pos;
                    if ub <= tau {
                        self.stats.grads_skipped += 1;
                        false
                    } else {
                        true
                    }
                };
                if compute {
                    let (psi, mass) = group_grad_contrib(
                        alpha,
                        beta_j,
                        c_j,
                        self.prob.groups.range(l),
                        tau,
                        lq,
                        grad_alpha,
                        &mut self.grad_scratch,
                    );
                    psi_total += psi;
                    col_mass += mass;
                    grads_this_eval += 1;
                }
            }
            grad_beta[j] += col_mass;
        }

        self.stats.grads_computed += grads_this_eval;
        self.stats.record_eval(grads_this_eval);

        let dual = linalg::dot(alpha, &self.prob.a) + linalg::dot(beta, &self.prob.b) - psi_total;
        -dual
    }

    /// Algorithm 1, lines 4–15: rebuild ℕ from the old snapshots, then
    /// move the snapshots to the current iterate.
    fn refresh(&mut self, x: &[f64]) {
        let m = self.prob.m();
        if self.use_ws {
            self.rebuild_working_set(x);
        }
        self.snap_alpha.copy_from_slice(&x[..m]);
        self.snap_beta.copy_from_slice(&x[m..]);
        self.recompute_snapshots();
    }

    fn stats(&self) -> &OracleStats {
        &self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::rng::Pcg64;

    fn random_problem(seed: u64, l: usize, g: usize, n: usize) -> OtProblem {
        let mut rng = Pcg64::new(seed);
        let m = l * g;
        let cost = Mat::from_fn(m, n, |_, _| rng.uniform(0.0, 1.0));
        let labels: Vec<usize> = (0..m).map(|i| i / g).collect();
        OtProblem::from_parts(vec![1.0 / m as f64; m], vec![1.0 / n as f64; n], &cost, &labels)
    }

    /// Screened eval must equal dense eval exactly, at arbitrary points
    /// and snapshot states.
    #[test]
    fn screened_eval_equals_dense() {
        let prob = random_problem(3, 4, 3, 7);
        let params = DualParams::new(0.5, 0.6);
        for ws in [false, true] {
            let mut oracle = ScreeningOracle::new(&prob, params, ws);
            let mut rng = Pcg64::new(99);
            let mut x = vec![0.0; prob.dim()];
            for step in 0..12 {
                // Random walk; refresh snapshots at some steps.
                for v in x.iter_mut() {
                    *v += rng.uniform(-0.2, 0.25);
                }
                if step % 4 == 3 {
                    oracle.refresh(&x);
                }
                let mut g1 = vec![0.0; prob.dim()];
                let f1 = oracle.eval(&x, &mut g1);
                let mut g2 = vec![0.0; prob.dim()];
                let (f2, _) = super::super::dual::eval_dense(&prob, &params, &x, &mut g2);
                assert_eq!(f1, f2, "objective mismatch ws={ws} step={step}");
                assert_eq!(g1, g2, "gradient mismatch ws={ws} step={step}");
            }
        }
    }

    #[test]
    fn skips_happen_for_strong_regularization() {
        let prob = random_problem(5, 6, 4, 10);
        // Large τ ⇒ lots of zero groups ⇒ skips after a refresh.
        let params = DualParams::new(5.0, 0.8);
        let mut oracle = ScreeningOracle::new(&prob, params, true);
        let x = vec![0.01; prob.dim()];
        oracle.refresh(&x);
        let mut g = vec![0.0; prob.dim()];
        oracle.eval(&x, &mut g);
        let s = oracle.stats();
        assert!(s.grads_skipped > 0, "expected skips, got {s:?}");
    }

    #[test]
    fn working_set_members_bypass_checks() {
        let prob = random_problem(7, 3, 5, 8);
        // Small τ ⇒ most groups active ⇒ ℕ should be non-empty after a
        // refresh near a well-separated point.
        let params = DualParams::new(0.05, 0.3);
        let mut oracle = ScreeningOracle::new(&prob, params, true);
        let mut x = vec![0.0; prob.dim()];
        // Push α, β up so f = α + β − c is clearly positive.
        for v in x.iter_mut() {
            *v = 1.0;
        }
        oracle.refresh(&x); // snapshots at x
        oracle.refresh(&x); // Δ=0 now; lower bound = k̃ − õ = z̃ exactly
        assert!(oracle.working_set_density() > 0.0);
        let before = oracle.stats().ws_hits;
        let mut g = vec![0.0; prob.dim()];
        oracle.eval(&x, &mut g);
        assert!(oracle.stats().ws_hits > before);
    }

    #[test]
    fn bounds_are_valid_at_random_points() {
        // z̲ ≤ z ≤ z̄ for random snapshots and iterates.
        let prob = random_problem(11, 4, 4, 6);
        let params = DualParams::new(1.0, 0.5);
        let mut oracle = ScreeningOracle::new(&prob, params, true);
        let mut rng = Pcg64::new(1234);
        let mut x = vec![0.0; prob.dim()];
        for _ in 0..8 {
            for v in x.iter_mut() {
                *v += rng.uniform(-0.3, 0.35);
            }
            let errs = oracle.bound_errors(&x);
            // mean_upper = mean(z̄ − z) ≥ 0 and mean_lower = mean(z − z̲) ≥ 0.
            assert!(errs.mean_upper >= -1e-12, "{errs:?}");
            assert!(errs.mean_lower >= -1e-12, "{errs:?}");
            if rng.f64() < 0.5 {
                oracle.refresh(&x);
            }
        }
    }

    #[test]
    fn bounds_tight_at_snapshot_point() {
        // Theorem 3: at Δ = 0 the upper bound is exact.
        let prob = random_problem(13, 3, 3, 5);
        let params = DualParams::new(0.8, 0.4);
        let mut oracle = ScreeningOracle::new(&prob, params, true);
        let mut x = vec![0.0; prob.dim()];
        let mut rng = Pcg64::new(5);
        for v in x.iter_mut() {
            *v = rng.uniform(-0.5, 0.7);
        }
        oracle.refresh(&x);
        let errs = oracle.bound_errors(&x);
        assert!(errs.max_upper.abs() < 1e-12, "{errs:?}");
    }
}
