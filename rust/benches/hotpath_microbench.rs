//! Hot-path microbenchmarks for the performance pass (§Perf in
//! EXPERIMENTS.md): isolates the dense oracle evaluation, the screened
//! evaluation (high/low sparsity), snapshot refresh and working-set
//! construction so individual optimizations can be measured.

mod common;

use common::*;
use grpot::benchlib::{bench_fn, report_dir, BenchOptions, Table};
use grpot::data::synthetic;
use grpot::ot::dual::{DualOracle, DualParams};
use grpot::ot::origin::OriginOracle;
use grpot::ot::screening::ScreeningOracle;
use grpot::pool::{chunk_ranges, forkjoin_map_chunks, ParallelCtx};
use grpot::rng::Pcg64;

fn main() {
    banner("hotpath microbench");
    let l = size3(8, 40, 160);
    let pair = synthetic::controlled_classes(l, 10, 0x407B);
    let prob = problem_of(&pair);
    println!("problem: m=n={} |L|={}", prob.m(), l);

    let mut rng = Pcg64::new(3);
    // A dual point with mixed activity (some groups on, some off).
    let x: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.1, 0.15)).collect();
    let mut grad = vec![0.0; prob.dim()];
    let opts = BenchOptions { warmup: 2, iters: 15, max_seconds: 120.0 };

    let mut table = Table::new("hot-path microbenchmarks", &["case", "ms/op"]);
    let mut record = |name: &str, ms: f64| {
        println!("{name:<34} {ms:>9.3} ms");
        table.row(vec![name.into(), format!("{ms:.3}")]);
    };

    // Dense eval, serial and with 4 intra-eval oracle threads (results
    // are bit-identical; only the wall clock moves).
    let sparse_params = DualParams::new(5.0, 0.8); // strong reg ⇒ sparse
    let dense_params = DualParams::new(0.01, 0.2); // weak reg ⇒ dense
    for (tag, params) in [("sparse", sparse_params), ("dense", dense_params)] {
        for threads in [1usize, 4] {
            let mut origin = OriginOracle::with_threads(&prob, params, threads);
            let t = bench_fn("origin", &opts, || {
                origin.eval(&x, &mut grad);
            });
            record(&format!("origin eval ({tag}, {threads}t)"), t.seconds() * 1e3);

            let mut screen = ScreeningOracle::with_threads(&prob, params, true, threads);
            screen.refresh(&x);
            let t = bench_fn("screen", &opts, || {
                screen.eval(&x, &mut grad);
            });
            record(&format!("screened eval ({tag}, {threads}t)"), t.seconds() * 1e3);
        }
    }

    // Snapshot refresh (the O(mn) periodic cost), serial vs threaded.
    for threads in [1usize, 4] {
        let mut screen = ScreeningOracle::with_threads(&prob, sparse_params, true, threads);
        let t = bench_fn("refresh", &opts, || {
            screen.refresh(&x);
        });
        record(&format!("snapshot + ws refresh ({threads}t)"), t.seconds() * 1e3);
    }

    // Bare dispatch latency on a near-empty job — the per-eval floor the
    // screened sparse regime pays: persistent parked handoff vs the
    // PR-3 scoped fork-join over the same 32-chunk grid.
    let ranges = chunk_ranges(32 * 16, 16);
    let mut slots = vec![0u64; ranges.len()];
    let touch = |c: usize, _range: std::ops::Range<usize>, slot: &mut u64| {
        *slot = c as u64;
    };
    let ctx = ParallelCtx::new(4);
    ctx.map_chunks(&ranges, &mut slots, touch); // spawn outside timing
    let t = bench_fn("dispatch-persistent", &opts, || {
        ctx.map_chunks(&ranges, &mut slots, touch);
    });
    record("dispatch persistent (4t, empty)", t.seconds() * 1e3);
    let t = bench_fn("dispatch-forkjoin", &opts, || {
        forkjoin_map_chunks(4, &ranges, &mut slots, touch);
    });
    record("dispatch fork-join (4t, empty)", t.seconds() * 1e3);

    table.emit(&report_dir(), "hotpath_microbench");
}
