//! Domain-adaptation evaluation: transport the labeled source into the
//! target domain and measure 1-NN transfer accuracy (the standard OTDA
//! protocol of Courty et al. 2017).

use crate::data::DomainPair;
use crate::linalg::{self, Mat};
use crate::ot::dual::OtProblem;
use crate::ot::plan::TransportPlan;

/// 1-nearest-neighbour classification of `queries` against labeled
/// `refs`; returns predicted labels.
pub fn knn1_predict(refs: &Mat, ref_labels: &[usize], queries: &Mat) -> Vec<usize> {
    assert_eq!(refs.rows(), ref_labels.len());
    assert_eq!(refs.cols(), queries.cols());
    let d = linalg::sq_euclidean_cost(queries, refs); // q × r
    (0..queries.rows())
        .map(|q| {
            let row = d.row(q);
            let mut best = 0usize;
            let mut best_d = f64::INFINITY;
            for (r, &v) in row.iter().enumerate() {
                if v < best_d {
                    best_d = v;
                    best = r;
                }
            }
            ref_labels[best]
        })
        .collect()
}

/// Fraction of matching labels.
pub fn accuracy(pred: &[usize], truth: &[usize]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    let hits = pred.iter().zip(truth).filter(|(p, t)| p == t).count();
    hits as f64 / pred.len() as f64
}

/// OTDA evaluation: barycentrically map the source samples through the
/// plan, 1-NN-classify the target against the mapped (still labeled)
/// source, and score against the target's ground-truth labels (held out
/// from the solver).
pub fn otda_accuracy(pair: &DomainPair, prob: &OtProblem, plan: &TransportPlan) -> f64 {
    // Plan rows are in sorted order; labels of sorted rows:
    let sorted_labels: Vec<usize> = prob
        .groups
        .perm
        .iter()
        .map(|&orig| pair.source.labels[orig])
        .collect();
    let mapped = plan.barycentric_map(&pair.target.x);
    // Rows that moved no mass are meaningless references; drop them.
    let row_mass = plan.t.row_sums();
    let keep: Vec<usize> = (0..mapped.rows()).filter(|&i| row_mass[i] > 1e-12).collect();
    assert!(!keep.is_empty(), "plan moved no mass at all");
    let mut refs = Mat::zeros(keep.len(), mapped.cols());
    let mut ref_labels = Vec::with_capacity(keep.len());
    for (r, &i) in keep.iter().enumerate() {
        refs.row_mut(r).copy_from_slice(mapped.row(i));
        ref_labels.push(sorted_labels[i]);
    }
    let pred = knn1_predict(&refs, &ref_labels, &pair.target.x);
    accuracy(&pred, &pair.target.labels)
}

/// Baseline: 1-NN straight across the domain gap (no adaptation).
pub fn no_adaptation_accuracy(pair: &DomainPair) -> f64 {
    let pred = knn1_predict(&pair.source.x, &pair.source.labels, &pair.target.x);
    accuracy(&pred, &pair.target.labels)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic;
    use crate::ot::fastot::{solve_fast_ot, FastOtConfig};
    use crate::ot::plan::recover_plan;

    #[test]
    fn knn_identifies_exact_matches() {
        let refs = Mat::from_vec(3, 2, vec![0.0, 0.0, 5.0, 5.0, -5.0, 5.0]);
        let labels = vec![0, 1, 2];
        let queries = Mat::from_vec(2, 2, vec![4.9, 5.1, 0.1, -0.1]);
        assert_eq!(knn1_predict(&refs, &labels, &queries), vec![1, 0]);
    }

    #[test]
    fn accuracy_counts() {
        assert_eq!(accuracy(&[1, 2, 3], &[1, 2, 4]), 2.0 / 3.0);
        assert!(accuracy(&[], &[]).is_nan());
    }

    #[test]
    fn otda_beats_chance_on_synthetic() {
        // The synthetic construction has a severe y-axis shift, so OTDA
        // should recover class structure well above the 1/|L| chance.
        let pair = synthetic::controlled(5, 12, 77);
        let prob = OtProblem::from_dataset(&pair);
        let cfg = FastOtConfig { gamma: 0.05, rho: 0.6, ..Default::default() };
        let res = solve_fast_ot(&prob, &cfg);
        let plan = recover_plan(&prob, &cfg.params(), &res.x);
        let acc = otda_accuracy(&pair, &prob, &plan);
        assert!(acc > 0.6, "otda accuracy too low: {acc}");
    }
}
