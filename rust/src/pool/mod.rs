//! Thread-pool substrate (tokio/rayon are unavailable offline).
//!
//! Facilities:
//!
//! * [`ThreadPool`] — a fixed pool of workers consuming boxed jobs from a
//!   shared channel; used by the coordinator's sweep scheduler and the
//!   TCP service.
//! * [`BoundedQueue`] — a capacity-bounded MPMC FIFO whose `try_push`
//!   never blocks (the serving engine's admission-control substrate:
//!   overload surfaces as an immediate rejection, not unbounded memory).
//! * [`Semaphore`] — a counting semaphore (std has none on stable).
//! * [`parallel_for_chunks`] — fork-join data parallelism over an index
//!   range using `std::thread::scope`; used off the solver's hot path
//!   (dataset generation, evaluation) where thread-count-dependent
//!   chunking is acceptable.
//! * [`ParallelCtx`] / [`parallel_map_reduce`] — the solver hot path's
//!   *deterministic* fork-join facility: work is sharded over **fixed**
//!   chunks whose boundaries depend only on the problem size (never on
//!   the worker count), each chunk writes into its own slot, and partial
//!   results are combined in ascending chunk order on the calling thread
//!   — no atomics, no reduction races — so floating-point outputs are
//!   bit-identical for every thread count, including 1.

use std::collections::VecDeque;
use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Fixed-size thread pool. Jobs are executed FIFO; `join` blocks until
/// every submitted job has finished. Dropping the pool joins workers.
pub struct ThreadPool {
    tx: Option<mpsc::Sender<Job>>,
    workers: Vec<thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

impl ThreadPool {
    /// Create a pool with `n` workers (n ≥ 1).
    pub fn new(n: usize) -> Self {
        assert!(n >= 1, "pool needs at least one worker");
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let workers = (0..n)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let pending = Arc::clone(&pending);
                thread::Builder::new()
                    .name(format!("grpot-worker-{i}"))
                    .spawn(move || loop {
                        let job = { rx.lock().unwrap().recv() };
                        match job {
                            Ok(job) => {
                                job();
                                let (lock, cvar) = &*pending;
                                let mut p = lock.lock().unwrap();
                                *p -= 1;
                                if *p == 0 {
                                    cvar.notify_all();
                                }
                            }
                            Err(_) => break, // channel closed: shut down
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { tx: Some(tx), workers, pending }
    }

    /// Number of workers.
    pub fn size(&self) -> usize {
        self.workers.len()
    }

    /// Submit a job.
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) {
        {
            let (lock, _) = &*self.pending;
            *lock.lock().unwrap() += 1;
        }
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(Box::new(job))
            .expect("worker channel closed");
    }

    /// Block until all submitted jobs have completed.
    pub fn join(&self) {
        let (lock, cvar) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cvar.wait(p).unwrap();
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.tx.take(); // close the channel; workers exit their loop
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Counting semaphore (std has none on stable): used by the TCP service
/// to cap concurrent solves while connections run thread-per-socket.
pub struct Semaphore {
    state: Mutex<usize>,
    cvar: std::sync::Condvar,
}

/// RAII permit; releases on drop.
pub struct SemaphorePermit<'a> {
    sem: &'a Semaphore,
}

impl Semaphore {
    pub fn new(permits: usize) -> Self {
        assert!(permits > 0);
        Semaphore { state: Mutex::new(permits), cvar: std::sync::Condvar::new() }
    }

    /// Block until a permit is available.
    pub fn acquire(&self) -> SemaphorePermit<'_> {
        let mut avail = self.state.lock().unwrap();
        while *avail == 0 {
            avail = self.cvar.wait(avail).unwrap();
        }
        *avail -= 1;
        SemaphorePermit { sem: self }
    }

    /// Current free permits (diagnostics).
    pub fn available(&self) -> usize {
        *self.state.lock().unwrap()
    }
}

impl Drop for SemaphorePermit<'_> {
    fn drop(&mut self) {
        let mut avail = self.sem.state.lock().unwrap();
        *avail += 1;
        self.sem.cvar.notify_one();
    }
}

/// Why `try_push` failed; the rejected item is handed back so callers
/// can report on it (e.g. answer the request with a structured error).
#[derive(Debug)]
pub enum PushError<T> {
    /// The queue held `capacity` items already.
    Full(T),
    /// [`BoundedQueue::close`] was called; no further items are accepted.
    Closed(T),
}

struct BoundedState<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Capacity-bounded MPMC FIFO. Producers never block: `try_push` fails
/// immediately when the queue is full (backpressure) or closed.
/// Consumers block in `pop` until an item arrives; after `close`, `pop`
/// drains the remaining items and then returns `None`.
pub struct BoundedQueue<T> {
    state: Mutex<BoundedState<T>>,
    cvar: Condvar,
    capacity: usize,
}

impl<T> BoundedQueue<T> {
    /// Create with a hard capacity (≥ 1).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity >= 1, "queue needs capacity >= 1");
        BoundedQueue {
            state: Mutex::new(BoundedState { items: VecDeque::new(), closed: false }),
            cvar: Condvar::new(),
            capacity,
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Enqueue without blocking. Returns the queue depth after the push,
    /// or the item wrapped in the reason it was refused.
    pub fn try_push(&self, item: T) -> Result<usize, PushError<T>> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(PushError::Closed(item));
        }
        if st.items.len() >= self.capacity {
            return Err(PushError::Full(item));
        }
        st.items.push_back(item);
        let depth = st.items.len();
        drop(st);
        self.cvar.notify_one();
        Ok(depth)
    }

    /// Dequeue, blocking until an item is available. Returns `None` only
    /// once the queue is closed *and* drained.
    pub fn pop(&self) -> Option<T> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(item) = st.items.pop_front() {
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.cvar.wait(st).unwrap();
        }
    }

    /// Non-blocking dequeue.
    pub fn try_pop(&self) -> Option<T> {
        self.state.lock().unwrap().items.pop_front()
    }

    /// Remove up to `max` items satisfying `pred`, preserving FIFO order
    /// among both the taken and the remaining items. Non-blocking; used
    /// by the micro-batcher to coalesce same-dataset requests.
    pub fn drain_matching(&self, max: usize, mut pred: impl FnMut(&T) -> bool) -> Vec<T> {
        let mut st = self.state.lock().unwrap();
        let mut taken = Vec::new();
        let mut rest = VecDeque::with_capacity(st.items.len());
        while let Some(item) = st.items.pop_front() {
            if taken.len() < max && pred(&item) {
                taken.push(item);
            } else {
                rest.push_back(item);
            }
        }
        st.items = rest;
        taken
    }

    /// Current depth.
    pub fn len(&self) -> usize {
        self.state.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Refuse new items and wake every blocked consumer. Items already
    /// queued remain poppable (graceful drain).
    pub fn close(&self) {
        self.state.lock().unwrap().closed = true;
        self.cvar.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.state.lock().unwrap().closed
    }
}

/// Run `body(chunk_start, chunk_end)` over `0..n` split into contiguous
/// chunks across `threads` scoped threads. `body` must be `Sync`-safe via
/// captured shared state; results are typically written to disjoint
/// slices by the caller.
pub fn parallel_for_chunks<F>(n: usize, threads: usize, body: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1).min(n.max(1));
    if threads <= 1 || n == 0 {
        body(0, n);
        return;
    }
    let chunk = n.div_ceil(threads);
    thread::scope(|s| {
        for t in 0..threads {
            let lo = t * chunk;
            let hi = ((t + 1) * chunk).min(n);
            if lo >= hi {
                break;
            }
            let body = &body;
            s.spawn(move || body(lo, hi));
        }
    });
}

/// Upper bound on the number of fixed chunks produced by
/// [`fixed_chunk_ranges`]. Bounds both the per-chunk scratch memory the
/// oracles keep resident and the ordered-reduction cost.
pub const MAX_FIXED_CHUNKS: usize = 32;

/// Lower bound on indices per fixed chunk: tiny problems collapse to a
/// single chunk instead of paying fork-join overhead per column.
pub const MIN_FIXED_CHUNK_LEN: usize = 16;

/// Chunk length used by [`fixed_chunk_ranges`] for a range of `n`
/// indices. A function of `n` **only** — never of the worker count —
/// which is what makes chunked reductions thread-count-invariant.
pub fn fixed_chunk_len(n: usize) -> usize {
    n.div_ceil(MAX_FIXED_CHUNKS).max(MIN_FIXED_CHUNK_LEN)
}

/// Split `0..n` into contiguous ranges of `chunk` indices (last may be
/// short). `n = 0` yields no ranges.
pub fn chunk_ranges(n: usize, chunk: usize) -> Vec<Range<usize>> {
    assert!(chunk >= 1, "chunk length must be >= 1");
    let mut out = Vec::with_capacity(n.div_ceil(chunk));
    let mut lo = 0;
    while lo < n {
        let hi = (lo + chunk).min(n);
        out.push(lo..hi);
        lo = hi;
    }
    out
}

/// The fixed, thread-count-independent chunking of `0..n` used by the
/// column-parallel oracles: at most [`MAX_FIXED_CHUNKS`] chunks of at
/// least [`MIN_FIXED_CHUNK_LEN`] indices each.
pub fn fixed_chunk_ranges(n: usize) -> Vec<Range<usize>> {
    chunk_ranges(n, fixed_chunk_len(n))
}

/// Intra-solve parallelism context: how many worker threads a solver's
/// oracle may fork per evaluation. `threads = 1` (the default
/// everywhere) runs the identical chunked code path serially, so the
/// paper-faithful single-core configuration and the multicore one
/// produce byte-equal iterates.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ParallelCtx {
    threads: usize,
}

impl Default for ParallelCtx {
    fn default() -> Self {
        ParallelCtx::serial()
    }
}

impl ParallelCtx {
    /// Create with `threads` workers (0 is treated as 1).
    pub fn new(threads: usize) -> Self {
        ParallelCtx { threads: threads.max(1) }
    }

    /// The single-threaded context (still runs the chunked code path).
    pub fn serial() -> Self {
        ParallelCtx::new(1)
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    pub fn is_parallel(&self) -> bool {
        self.threads > 1
    }

    /// Fork-join map over pre-chunked work: `map(chunk_idx, range, slot)`
    /// runs once per chunk with exclusive access to that chunk's slot.
    /// Chunk→slot assignment is by index and chunk boundaries come from
    /// the caller, so *which thread* ran a chunk can never influence the
    /// result; callers then combine slots in chunk order for a
    /// deterministic reduction. A panic in any worker propagates to the
    /// caller when the scope joins.
    pub fn map_chunks<S, F>(&self, ranges: &[Range<usize>], slots: &mut [S], map: F)
    where
        S: Send,
        F: Fn(usize, Range<usize>, &mut S) + Sync,
    {
        assert_eq!(ranges.len(), slots.len(), "one slot per chunk");
        let k = ranges.len();
        if k == 0 {
            return;
        }
        let workers = self.threads.min(k);
        if workers <= 1 {
            for (c, slot) in slots.iter_mut().enumerate() {
                map(c, ranges[c].clone(), slot);
            }
            return;
        }
        // Static contiguous assignment: worker b owns chunk indices
        // [b·per, (b+1)·per). Column costs are near-uniform, so static
        // splitting balances fine without work-stealing overhead.
        //
        // Scoped threads are spawned per call (tens of µs of fork-join
        // overhead per eval) — fine while chunk work dominates, i.e. on
        // the large problems worth threading at all. If bench_parallel
        // shows the screened sparse regime starved by spawn cost, the
        // upgrade path is a persistent parked worker set inside
        // ParallelCtx with the same chunk→slot assignment; the ordered
        // reduction (and thus bit-exactness) is unaffected by who runs
        // a chunk.
        let per = k.div_ceil(workers);
        thread::scope(|s| {
            for (b, block) in slots.chunks_mut(per).enumerate() {
                let map = &map;
                s.spawn(move || {
                    for (off, slot) in block.iter_mut().enumerate() {
                        let c = b * per + off;
                        map(c, ranges[c].clone(), slot);
                    }
                });
            }
        });
    }
}

/// Deterministic sharded map-reduce over `0..n` in fixed chunks of
/// `chunk` indices: `map(chunk_idx, range)` runs fork-join style on up
/// to `threads` workers, and `reduce(acc, value)` folds the chunk
/// values **in ascending chunk order** on the calling thread — per-chunk
/// partials, never atomics — so the result is bit-identical for every
/// `threads`, including 1. `n = 0` returns `init` without calling `map`;
/// `chunk > n` degenerates to one chunk. Panics in `map` propagate.
pub fn parallel_map_reduce<T, A, M, R>(
    threads: usize,
    n: usize,
    chunk: usize,
    init: A,
    map: M,
    mut reduce: R,
) -> A
where
    T: Send,
    M: Fn(usize, Range<usize>) -> T + Sync,
    R: FnMut(A, T) -> A,
{
    let ranges = chunk_ranges(n, chunk.max(1));
    let mut slots: Vec<Option<T>> = Vec::new();
    slots.resize_with(ranges.len(), || None);
    ParallelCtx::new(threads).map_chunks(&ranges, &mut slots, |c, range, slot| {
        *slot = Some(map(c, range));
    });
    let mut acc = init;
    for slot in slots {
        acc = reduce(acc, slot.expect("every chunk was mapped"));
    }
    acc
}

/// Dynamic work-stealing-ish variant: threads atomically grab blocks of
/// `block` indices until the range is exhausted. Better for ragged work
/// (e.g. sweep jobs with very different solve times).
pub fn parallel_for_dynamic<F>(n: usize, threads: usize, block: usize, body: F)
where
    F: Fn(usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || n <= block {
        for i in 0..n {
            body(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let body = &body;
            s.spawn(move || loop {
                let start = next.fetch_add(block, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                for i in start..(start + block).min(n) {
                    body(i);
                }
            });
        }
    });
}

#[cfg(test)]
mod tests;
