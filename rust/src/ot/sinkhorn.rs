//! Entropic OT baselines.
//!
//! * [`sinkhorn_log`] — log-domain stabilized Sinkhorn (Cuturi 2013 with
//!   the stabilization of Schmitzer 2019). The paper's related-work
//!   comparator and a substrate for the GCG solver below.
//! * [`gcg_group_lasso`] — the ℓ1–ℓ2 group-regularized entropic OT of
//!   Courty et al. (2017), solved by generalized conditional gradient:
//!   the baseline the paper excluded for numerical instability (we keep
//!   it runnable for completeness). Note this regularizer does *not*
//!   achieve true group sparsity (entropic term keeps T > 0), which the
//!   domain-adaptation example demonstrates.

use crate::groups::GroupStructure;
use crate::linalg::{self, Mat};

/// Result of an entropic OT solve.
#[derive(Clone, Debug)]
pub struct SinkhornResult {
    /// Dense transport plan `m × n`.
    pub plan: Mat,
    /// Iterations used.
    pub iterations: usize,
    /// Final max marginal violation (L∞).
    pub marginal_error: f64,
    /// `⟨T, C⟩`.
    pub transport_cost: f64,
}

/// Log-domain Sinkhorn. `reg` is the entropic ε; smaller ε approaches
/// the exact LP but needs more iterations.
pub fn sinkhorn_log(
    a: &[f64],
    b: &[f64],
    cost: &Mat,
    reg: f64,
    max_iters: usize,
    tol: f64,
) -> SinkhornResult {
    let m = a.len();
    let n = b.len();
    assert_eq!(cost.shape(), (m, n));
    assert!(reg > 0.0);
    let log_a: Vec<f64> = a.iter().map(|&x| x.ln()).collect();
    let log_b: Vec<f64> = b.iter().map(|&x| x.ln()).collect();
    // `1/ε` hoisted out of the per-entry loops: the inner updates touch
    // every (i, j) once per iteration, and a multiply is cheaper than a
    // division on every current core.
    let inv_reg = 1.0 / reg;
    let mut f = vec![0.0; m]; // dual potential for a
    let mut g = vec![0.0; n]; // dual potential for b
    let mut iterations = 0;
    let mut err = f64::INFINITY;
    let mut scratch = vec![0.0; n.max(m)];
    for it in 0..max_iters {
        iterations = it + 1;
        // f update: f_i = ε·log a_i − ε·LSE_j((g_j − C_ij)/ε)
        for i in 0..m {
            let row = cost.row(i);
            for j in 0..n {
                scratch[j] = (g[j] - row[j]) * inv_reg;
            }
            f[i] = reg * (log_a[i] - linalg::logsumexp(&scratch[..n]));
        }
        // g update
        for j in 0..n {
            for i in 0..m {
                scratch[i] = (f[i] - cost[(i, j)]) * inv_reg;
            }
            g[j] = reg * (log_b[j] - linalg::logsumexp(&scratch[..m]));
        }
        // Row-marginal error every few iterations (g update enforces cols).
        if it % 5 == 4 || it + 1 == max_iters {
            err = 0.0;
            for i in 0..m {
                let row = cost.row(i);
                let mut s = 0.0;
                for j in 0..n {
                    s += ((f[i] + g[j] - row[j]) * inv_reg).exp();
                }
                err = err.max((s - a[i]).abs());
            }
            if err < tol {
                break;
            }
        }
    }
    let mut plan = Mat::zeros(m, n);
    for i in 0..m {
        let row = cost.row(i);
        let prow = plan.row_mut(i);
        for j in 0..n {
            prow[j] = ((f[i] + g[j] - row[j]) * inv_reg).exp();
        }
    }
    let transport_cost = plan.frobenius_dot(cost);
    SinkhornResult { plan, iterations, marginal_error: err, transport_cost }
}

/// Options for the GCG ℓ1–ℓ2 group-lasso solver.
#[derive(Clone, Debug)]
pub struct GcgOptions {
    /// Entropic strength ε.
    pub reg_entropy: f64,
    /// Group-lasso strength η.
    pub reg_group: f64,
    /// Outer GCG iterations.
    pub max_outer: usize,
    /// Inner Sinkhorn iterations.
    pub max_inner: usize,
    /// Inner Sinkhorn tolerance.
    pub inner_tol: f64,
    /// Outer relative-change stopping tolerance.
    pub outer_tol: f64,
}

impl Default for GcgOptions {
    fn default() -> Self {
        GcgOptions {
            reg_entropy: 0.05,
            reg_group: 0.1,
            max_outer: 20,
            max_inner: 300,
            inner_tol: 1e-7,
            outer_tol: 1e-6,
        }
    }
}

/// ℓ1–ℓ2 group-lasso regularized entropic OT via generalized
/// conditional gradient (Courty et al. 2017):
/// `min ⟨T,C⟩ + ε·H(T) + η·Σ_{j,l} ‖T_{[l],j}‖₂`.
pub fn gcg_group_lasso(
    a: &[f64],
    b: &[f64],
    cost: &Mat,
    groups: &GroupStructure,
    opts: &GcgOptions,
) -> SinkhornResult {
    let m = a.len();
    let n = b.len();
    let eps = opts.reg_entropy;
    let eta = opts.reg_group;

    let omega = |t: &Mat| -> f64 {
        let mut s = 0.0;
        for j in 0..n {
            for l in 0..groups.num_groups() {
                let mut q = 0.0;
                for i in groups.range(l) {
                    q += t[(i, j)] * t[(i, j)];
                }
                s += q.sqrt();
            }
        }
        s
    };
    let entropy = |t: &Mat| -> f64 {
        t.as_slice()
            .iter()
            .map(|&v| if v > 0.0 { v * (v.ln() - 1.0) } else { 0.0 })
            .sum()
    };
    let objective =
        |t: &Mat| -> f64 { t.frobenius_dot(cost) + eps * entropy(t) + eta * omega(t) };

    // Init: plain entropic plan.
    let mut t = sinkhorn_log(a, b, cost, eps, opts.max_inner, opts.inner_tol).plan;
    let mut obj = objective(&t);
    let mut iterations = 0;
    for outer in 0..opts.max_outer {
        iterations = outer + 1;
        // Linearize the group term: grad_ij = t_ij / ‖t_{[l],j}‖ (0-safe).
        let mut lin = cost.clone();
        for j in 0..n {
            for l in 0..groups.num_groups() {
                let mut q = 0.0;
                for i in groups.range(l) {
                    q += t[(i, j)] * t[(i, j)];
                }
                let nrm = q.sqrt();
                if nrm > 1e-300 {
                    for i in groups.range(l) {
                        lin[(i, j)] += eta * t[(i, j)] / nrm;
                    }
                }
            }
        }
        // Solve the linearized entropic problem.
        let cand = sinkhorn_log(a, b, &lin, eps, opts.max_inner, opts.inner_tol).plan;
        // Line search over the segment T + s(T̂ − T), s ∈ (0, 1].
        let mut best_s = 0.0;
        let mut best_obj = obj;
        for k in 1..=20 {
            let s = k as f64 / 20.0;
            let mut ts = t.clone();
            for (v, &c) in ts.as_mut_slice().iter_mut().zip(cand.as_slice()) {
                *v = (1.0 - s) * *v + s * c;
            }
            let o = objective(&ts);
            if o < best_obj {
                best_obj = o;
                best_s = s;
            }
        }
        if best_s == 0.0 || (obj - best_obj).abs() <= opts.outer_tol * obj.abs().max(1.0) {
            if best_s > 0.0 {
                for (v, &c) in t.as_mut_slice().iter_mut().zip(cand.as_slice()) {
                    *v = (1.0 - best_s) * *v + best_s * c;
                }
            }
            break;
        }
        for (v, &c) in t.as_mut_slice().iter_mut().zip(cand.as_slice()) {
            *v = (1.0 - best_s) * *v + best_s * c;
        }
        obj = best_obj;
    }
    let rs = t.row_sums();
    let cs = t.col_sums();
    let mut err = 0.0f64;
    for i in 0..m {
        err = err.max((rs[i] - a[i]).abs());
    }
    for j in 0..n {
        err = err.max((cs[j] - b[j]).abs());
    }
    let transport_cost = t.frobenius_dot(cost);
    SinkhornResult { plan: t, iterations, marginal_error: err, transport_cost }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> (Vec<f64>, Vec<f64>, Mat) {
        let a = vec![0.5, 0.5];
        let b = vec![0.5, 0.5];
        let c = Mat::from_vec(2, 2, vec![0.0, 1.0, 1.0, 0.0]);
        (a, b, c)
    }

    #[test]
    fn sinkhorn_matches_identity_coupling() {
        let (a, b, c) = toy();
        let r = sinkhorn_log(&a, &b, &c, 0.01, 2000, 1e-10);
        // Optimal plan is diag(0.5, 0.5); entropic plan approaches it.
        assert!((r.plan[(0, 0)] - 0.5).abs() < 1e-3, "{:?}", r.plan);
        assert!(r.plan[(0, 1)] < 1e-3);
        assert!(r.transport_cost < 0.01);
        assert!(r.marginal_error < 1e-8);
    }

    #[test]
    fn sinkhorn_respects_marginals() {
        let a = vec![0.2, 0.3, 0.5];
        let b = vec![0.6, 0.4];
        let c = Mat::from_vec(3, 2, vec![0.3, 0.7, 0.2, 0.9, 0.8, 0.1]);
        let r = sinkhorn_log(&a, &b, &c, 0.05, 3000, 1e-10);
        let rs = r.plan.row_sums();
        let cs = r.plan.col_sums();
        for (got, want) in rs.iter().zip(&a) {
            assert!((got - want).abs() < 1e-6);
        }
        for (got, want) in cs.iter().zip(&b) {
            assert!((got - want).abs() < 1e-6);
        }
    }

    #[test]
    fn sinkhorn_stable_for_tiny_reg() {
        // Log-domain must survive ε = 1e-3 on an ill-scaled cost.
        let a = vec![0.5, 0.5];
        let b = vec![0.5, 0.5];
        let c = Mat::from_vec(2, 2, vec![0.0, 10.0, 10.0, 0.0]);
        let r = sinkhorn_log(&a, &b, &c, 1e-3, 500, 1e-9);
        assert!(r.plan.as_slice().iter().all(|v| v.is_finite()));
        assert!((r.plan[(0, 0)] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn gcg_group_lasso_runs_and_improves_grouping() {
        // 2 groups × 2 samples → 2 targets; group-friendly cost.
        let a = vec![0.25; 4];
        let b = vec![0.5, 0.5];
        let c = Mat::from_vec(
            4,
            2,
            vec![0.1, 0.9, 0.15, 0.85, 0.9, 0.1, 0.85, 0.15],
        );
        let groups = GroupStructure::from_labels(&[0, 0, 1, 1]);
        let plain = sinkhorn_log(&a, &b, &c, 0.05, 500, 1e-9);
        let gl = gcg_group_lasso(
            &a,
            &b,
            &c,
            &groups,
            &GcgOptions { reg_group: 0.5, ..Default::default() },
        );
        assert!(gl.plan.as_slice().iter().all(|v| v.is_finite() && *v >= 0.0));
        // Group-regularized mass of group 0 concentrates on target 0 at
        // least as much as plain Sinkhorn's.
        let mass = |p: &Mat| p[(0, 0)] + p[(1, 0)];
        assert!(mass(&gl.plan) >= mass(&plain.plan) - 1e-9);
        assert!(gl.marginal_error < 1e-4);
    }
}
