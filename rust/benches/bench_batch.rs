//! Batched-vs-sequential solve benchmark (ISSUE 10's tentpole payoff):
//! K (γ, ρ) problems over one dataset, solved K-times sequentially vs
//! once through the fused `solve_batched` lockstep pass. The fused pass
//! reads each surviving cost segment once per group instead of once per
//! lane, so the win is data movement — results are *asserted*
//! byte-equal before a single timing iteration runs, making the gain
//! impossible to buy with drift.
//!
//! Honors the standard bench modes (`GRPOT_BENCH_SMOKE`,
//! `GRPOT_BENCH_QUICK`); emits `reports/bench_batch.{md,csv}`.

mod common;

use common::*;
use grpot::benchlib::{bench_fn, report_dir, BenchOptions, Table};
use grpot::data::synthetic;
use grpot::ot::batch::solve_batched;
use grpot::ot::fastot;
use grpot::ot::regularizer::RegKind;
use grpot::ot::solve::SolveOptions;

/// K heterogeneous lanes off a fixed (γ, ρ) grid, group-lasso (the
/// batchable regularizer), serial oracle.
fn lane_opts(k: usize, max_iters: usize) -> Vec<SolveOptions> {
    const GAMMAS: [f64; 8] = [0.2, 0.7, 1.5, 4.0, 0.1, 9.0, 0.4, 2.5];
    const RHOS: [f64; 8] = [0.3, 0.6, 0.8, 0.45, 0.2, 0.7, 0.55, 0.35];
    (0..k)
        .map(|i| {
            SolveOptions::new()
                .gamma(GAMMAS[i % 8])
                .rho(RHOS[i % 8])
                .max_iters(max_iters)
                .regularizer(RegKind::GroupLasso)
        })
        .collect()
}

fn main() {
    banner("batched solve");
    let l = size3(6, 24, 80);
    let pair = synthetic::controlled_classes(l, 10, 0xBA7C);
    let prob = problem_of(&pair);
    let mi = size3(15, 60, 200);
    println!("problem: m=n={} |L|={} max_iters={mi}", prob.m(), l);
    let opts = BenchOptions { warmup: 1, iters: size3(2, 6, 12), max_seconds: 180.0 };

    let mut table = Table::new(
        "batched vs sequential solves",
        &["K", "t_seq[ms]", "t_batch[ms]", "speedup", "equal"],
    );
    for k in [2usize, 4, 8] {
        let lanes = lane_opts(k, mi);
        // The correctness gate: every batched lane must byte-equal its
        // sequential solve *before* anything is timed.
        let batched = solve_batched(&prob, &lanes).expect("batched solve");
        for (i, o) in lanes.iter().enumerate() {
            let seq = fastot::solve(&prob, o).expect("sequential solve");
            assert_eq!(batched[i].x, seq.x, "K={k} lane {i}: solution bytes diverged");
            assert_eq!(
                batched[i].dual_objective, seq.dual_objective,
                "K={k} lane {i}: objective diverged"
            );
            assert_eq!(
                batched[i].iterations, seq.iterations,
                "K={k} lane {i}: iteration count diverged"
            );
        }
        let t_seq = bench_fn("sequential", &opts, || {
            for o in &lanes {
                let _ = fastot::solve(&prob, o).expect("sequential solve");
            }
        })
        .seconds()
            * 1e3;
        let t_batch = bench_fn("batched", &opts, || {
            let _ = solve_batched(&prob, &lanes).expect("batched solve");
        })
        .seconds()
            * 1e3;
        let speedup = t_seq / t_batch.max(1e-9);
        println!("K={k:<2} sequential {t_seq:>9.2} ms  batched {t_batch:>9.2} ms  {speedup:.2}x");
        table.row(vec![
            format!("{k}"),
            format!("{t_seq:.2}"),
            format!("{t_batch:.2}"),
            format!("{speedup:.2}x"),
            "ok".into(), // the asserts above abort on any mismatch
        ]);
    }
    table.emit(&report_dir(), "bench_batch");
}
