//! Minimal error substrate (`anyhow` is unavailable in this offline
//! image).
//!
//! [`GrpotError`] is a string-backed error with `anyhow`-style context
//! chaining through the [`Context`] extension trait and the [`err!`] /
//! [`bail!`] macros. It is deliberately small: every fallible path in
//! the crate either bubbles a message up to the CLI/service boundary or
//! is asserted on in tests — no error needs to be matched structurally.

use std::fmt;

/// Crate-wide error: a human-readable message, with any causal chain
/// already folded into the text (`"context: cause"`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GrpotError(pub String);

impl GrpotError {
    /// Build from any displayable message.
    pub fn msg(m: impl fmt::Display) -> GrpotError {
        GrpotError(m.to_string())
    }
}

impl fmt::Display for GrpotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for GrpotError {}

/// Crate-wide result alias.
pub type Result<T, E = GrpotError> = std::result::Result<T, E>;

impl From<String> for GrpotError {
    fn from(s: String) -> GrpotError {
        GrpotError(s)
    }
}

impl From<&str> for GrpotError {
    fn from(s: &str) -> GrpotError {
        GrpotError(s.to_string())
    }
}

impl From<std::io::Error> for GrpotError {
    fn from(e: std::io::Error) -> GrpotError {
        GrpotError(format!("io error: {e}"))
    }
}

impl From<crate::jsonlite::ParseError> for GrpotError {
    fn from(e: crate::jsonlite::ParseError) -> GrpotError {
        GrpotError(e.to_string())
    }
}

impl From<crate::cli::CliError> for GrpotError {
    fn from(e: crate::cli::CliError) -> GrpotError {
        GrpotError(e.0)
    }
}

/// `anyhow::Context`-style extension for `Result` and `Option`.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a fixed context message.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Wrap with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| GrpotError(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| GrpotError(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| GrpotError(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| GrpotError(f().to_string()))
    }
}

/// Build a [`GrpotError`] from a format string (the local `anyhow!`).
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::error::GrpotError(format!($($arg)*))
    };
}

/// Return early with a [`GrpotError`] (the local `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_and_macros() {
        let e = err!("bad {} at {}", "thing", 7);
        assert_eq!(e.to_string(), "bad thing at 7");
        assert_eq!(format!("{e:#}"), "bad thing at 7");
        let f = || -> Result<()> { bail!("boom {}", 1) };
        assert_eq!(f().unwrap_err().0, "boom 1");
    }

    #[test]
    fn context_chains() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let e = r.context("reading config").unwrap_err();
        assert!(e.0.starts_with("reading config: "), "{e}");
        let n: Option<u32> = None;
        assert_eq!(n.context("no value").unwrap_err().0, "no value");
        let lazy: Option<u32> = None;
        let e = lazy.with_context(|| format!("missing {}", "x")).unwrap_err();
        assert_eq!(e.0, "missing x");
    }

    #[test]
    fn from_conversions() {
        let e: GrpotError = "plain".into();
        assert_eq!(e.0, "plain");
        let e: GrpotError = String::from("owned").into();
        assert_eq!(e.0, "owned");
        let io = std::io::Error::other("io boom");
        let e: GrpotError = io.into();
        assert!(e.0.contains("io boom"));
        let pe = crate::jsonlite::parse("{").unwrap_err();
        let e: GrpotError = pe.into();
        assert!(e.0.contains("json parse error"));
    }
}
