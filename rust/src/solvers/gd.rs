//! Gradient descent with Armijo backtracking — a slow-but-simple
//! reference solver used in tests to cross-check L-BFGS solutions.

use crate::linalg;
use crate::ot::dual::DualOracle;

/// Options for [`gradient_descent`].
#[derive(Clone, Debug)]
pub struct GdOptions {
    pub max_iters: usize,
    pub gtol: f64,
    /// Initial step size tried at each iteration.
    pub step0: f64,
    /// Backtracking shrink factor.
    pub shrink: f64,
    /// Armijo constant.
    pub c1: f64,
}

impl Default for GdOptions {
    fn default() -> Self {
        GdOptions { max_iters: 5000, gtol: 1e-6, step0: 1.0, shrink: 0.5, c1: 1e-4 }
    }
}

/// Minimize the oracle from `x0`; returns `(x, f, iters)`.
pub fn gradient_descent(
    oracle: &mut dyn DualOracle,
    x0: Vec<f64>,
    opts: &GdOptions,
) -> (Vec<f64>, f64, usize) {
    let n = x0.len();
    let mut x = x0;
    let mut g = vec![0.0; n];
    let mut f = oracle.eval(&x, &mut g);
    let mut xt = vec![0.0; n];
    let mut gt = vec![0.0; n];
    for iter in 0..opts.max_iters {
        let gnorm = linalg::nrm_inf(&g);
        if gnorm <= opts.gtol {
            return (x, f, iter);
        }
        let gsq = linalg::nrm2_sq(&g);
        let mut step = opts.step0;
        let mut accepted = false;
        for _ in 0..60 {
            for i in 0..n {
                xt[i] = x[i] - step * g[i];
            }
            let ft = oracle.eval(&xt, &mut gt);
            if ft <= f - opts.c1 * step * gsq {
                std::mem::swap(&mut x, &mut xt);
                std::mem::swap(&mut g, &mut gt);
                f = ft;
                accepted = true;
                break;
            }
            step *= opts.shrink;
        }
        if !accepted {
            return (x, f, iter);
        }
    }
    let iters = opts.max_iters;
    (x, f, iters)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ot::dual::OracleStats;

    struct Quad {
        stats: OracleStats,
    }
    impl DualOracle for Quad {
        fn shape(&self) -> (usize, usize) {
            (2, 0)
        }
        fn eval(&mut self, x: &[f64], g: &mut [f64]) -> f64 {
            self.stats.evals += 1;
            g[0] = x[0] - 2.0;
            g[1] = 3.0 * (x[1] + 1.0);
            0.5 * (x[0] - 2.0).powi(2) + 1.5 * (x[1] + 1.0).powi(2)
        }
        fn stats(&self) -> &OracleStats {
            &self.stats
        }
    }

    #[test]
    fn gd_converges_on_quadratic() {
        let mut o = Quad { stats: OracleStats::default() };
        let (x, f, _) = gradient_descent(&mut o, vec![10.0, 10.0], &GdOptions::default());
        assert!((x[0] - 2.0).abs() < 1e-4);
        assert!((x[1] + 1.0).abs() < 1e-4);
        assert!(f < 1e-8);
    }
}
