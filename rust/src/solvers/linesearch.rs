//! Strong-Wolfe line search (Nocedal & Wright, Algorithms 3.5/3.6).

use crate::ot::dual::DualOracle;

/// Line-search parameters.
#[derive(Clone, Copy, Debug)]
pub struct WolfeOptions {
    /// Sufficient-decrease constant (Armijo), typically 1e-4.
    pub c1: f64,
    /// Curvature constant, 0.9 for quasi-Newton directions.
    pub c2: f64,
    /// Maximum bracketing + zoom evaluations.
    pub max_evals: usize,
    /// Upper bound on the step length.
    pub step_max: f64,
}

impl Default for WolfeOptions {
    fn default() -> Self {
        WolfeOptions { c1: 1e-4, c2: 0.9, max_evals: 30, step_max: 1e6 }
    }
}

/// Result of a successful search.
pub struct LineSearchResult {
    pub step: f64,
    pub f: f64,
    /// Gradient at the accepted point (full-dimension).
    pub grad: Vec<f64>,
    pub evals: usize,
}

struct Phi<'a, 'b> {
    oracle: &'a mut dyn DualOracle,
    x0: &'b [f64],
    dir: &'b [f64],
    xt: Vec<f64>,
    gt: Vec<f64>,
    evals: usize,
}

impl Phi<'_, '_> {
    /// Evaluate φ(t) = f(x0 + t·d) and φ'(t) = ∇f(x0+t·d)ᵀd.
    fn eval(&mut self, t: f64) -> (f64, f64) {
        for ((xi, &x0i), &di) in self.xt.iter_mut().zip(self.x0).zip(self.dir) {
            *xi = x0i + t * di;
        }
        let f = self.oracle.eval(&self.xt, &mut self.gt);
        self.evals += 1;
        let dphi = crate::linalg::dot(&self.gt, self.dir);
        (f, dphi)
    }
}

/// Find a step satisfying the strong Wolfe conditions along `dir` from
/// `x0`. `f0`/`dphi0` are the value and directional derivative at 0
/// (`dphi0` must be negative). Returns `None` when no acceptable step is
/// found within the evaluation budget.
pub fn strong_wolfe(
    oracle: &mut dyn DualOracle,
    x0: &[f64],
    f0: f64,
    grad0: &[f64],
    dir: &[f64],
    init_step: f64,
    opts: &WolfeOptions,
) -> Option<LineSearchResult> {
    let dphi0 = crate::linalg::dot(grad0, dir);
    if dphi0 >= 0.0 {
        return None; // not a descent direction
    }
    let n = x0.len();
    let mut phi = Phi {
        oracle,
        x0,
        dir,
        xt: vec![0.0; n],
        gt: vec![0.0; n],
        evals: 0,
    };

    let mut t_prev = 0.0;
    let mut f_prev = f0;
    let mut dphi_prev = dphi0;
    let mut t = init_step.min(opts.step_max);

    for iter in 0..opts.max_evals {
        let (ft, dphit) = phi.eval(t);
        let armijo_ok = ft <= f0 + opts.c1 * t * dphi0;
        if !armijo_ok || (iter > 0 && ft >= f_prev) {
            return zoom(&mut phi, f0, dphi0, t_prev, f_prev, dphi_prev, t, ft, dphit, opts);
        }
        if dphit.abs() <= -opts.c2 * dphi0 {
            let evals = phi.evals;
            return Some(LineSearchResult { step: t, f: ft, grad: phi.gt, evals });
        }
        if dphit >= 0.0 {
            return zoom(&mut phi, f0, dphi0, t, ft, dphit, t_prev, f_prev, dphi_prev, opts);
        }
        t_prev = t;
        f_prev = ft;
        dphi_prev = dphit;
        t = (2.0 * t).min(opts.step_max);
        if t >= opts.step_max && iter > 3 {
            break;
        }
    }
    None
}

/// Zoom phase: maintain a bracket `[lo, hi]` containing an acceptable
/// step; interpolate (bisection with a cubic first guess).
#[allow(clippy::too_many_arguments)]
fn zoom(
    phi: &mut Phi,
    f0: f64,
    dphi0: f64,
    mut t_lo: f64,
    mut f_lo: f64,
    mut dphi_lo: f64,
    mut t_hi: f64,
    mut f_hi: f64,
    mut _dphi_hi: f64,
    opts: &WolfeOptions,
) -> Option<LineSearchResult> {
    for _ in 0..opts.max_evals {
        if (t_hi - t_lo).abs() < 1e-16 * t_lo.abs().max(1.0) {
            break;
        }
        // Cubic-ish guess via quadratic interpolation of (f_lo, dphi_lo, f_hi),
        // safeguarded into the middle 80% of the bracket.
        let mut t = quadratic_min(t_lo, f_lo, dphi_lo, t_hi, f_hi);
        let lo = t_lo.min(t_hi);
        let hi = t_lo.max(t_hi);
        let margin = 0.1 * (hi - lo);
        if !t.is_finite() || t < lo + margin || t > hi - margin {
            t = 0.5 * (lo + hi);
        }
        let (ft, dphit) = phi.eval(t);
        if ft > f0 + opts.c1 * t * dphi0 || ft >= f_lo {
            t_hi = t;
            f_hi = ft;
            _dphi_hi = dphit;
        } else {
            if dphit.abs() <= -opts.c2 * dphi0 {
                let evals = phi.evals;
                return Some(LineSearchResult { step: t, f: ft, grad: phi.gt.clone(), evals });
            }
            if dphit * (t_hi - t_lo) >= 0.0 {
                t_hi = t_lo;
                f_hi = f_lo;
                _dphi_hi = dphi_lo;
            }
            t_lo = t;
            f_lo = ft;
            dphi_lo = dphit;
        }
    }
    None
}

/// Minimizer of the quadratic through `(a, fa)` with slope `dfa` and `(b, fb)`.
fn quadratic_min(a: f64, fa: f64, dfa: f64, b: f64, fb: f64) -> f64 {
    let db = b - a;
    let denom = 2.0 * (fb - fa - dfa * db);
    if denom.abs() < 1e-300 {
        return f64::NAN;
    }
    a - dfa * db * db / denom
}
