//! `artifacts/manifest.json` parsing — the contract between
//! `python/compile/aot.py` and the Rust runtime.

use crate::err;
use crate::error::{Context, Result};
use crate::jsonlite;
use std::path::{Path, PathBuf};

/// One AOT-compiled program.
#[derive(Clone, Debug, PartialEq)]
pub struct ArtifactEntry {
    pub name: String,
    /// Program kind; currently "dual_obj_grad".
    pub kind: String,
    pub num_groups: usize,
    pub group_size: usize,
    pub m: usize,
    pub n: usize,
    /// HLO text file, relative to the manifest directory.
    pub file: String,
    pub sha256: String,
}

/// Parsed artifact index.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let v = jsonlite::parse(&text).context("parsing manifest json")?;
        let entries = v
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| err!("manifest missing 'entries'"))?;
        let mut out = Vec::with_capacity(entries.len());
        for e in entries {
            let get_str = |k: &str| -> Result<String> {
                Ok(e.get(k)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| err!("entry missing '{k}'"))?
                    .to_string())
            };
            let get_usize = |k: &str| -> Result<usize> {
                e.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| err!("entry missing '{k}'"))
            };
            out.push(ArtifactEntry {
                name: get_str("name")?,
                kind: get_str("kind")?,
                num_groups: get_usize("num_groups")?,
                group_size: get_usize("group_size")?,
                m: get_usize("m")?,
                n: get_usize("n")?,
                file: get_str("file")?,
                sha256: get_str("sha256")?,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries: out })
    }

    /// Find the dual-oracle artifact matching a problem shape.
    pub fn find_dual_oracle(
        &self,
        num_groups: usize,
        group_size: usize,
        n: usize,
    ) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| {
            e.kind == "dual_obj_grad"
                && e.num_groups == num_groups
                && e.group_size == group_size
                && e.n == n
        })
    }

    /// Absolute path of an entry's HLO file.
    pub fn path_of(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_entries_and_finds_shapes() {
        let dir = std::env::temp_dir().join(format!("grpot-manifest-{}", std::process::id()));
        write_manifest(
            &dir,
            r#"{"version": 1, "entries": [
                {"name": "x", "kind": "dual_obj_grad", "num_groups": 2,
                 "group_size": 3, "m": 6, "n": 4, "dtype": "f64",
                 "file": "x.hlo.txt", "sha256": "ab", "inputs": [], "outputs": []}
            ]}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.entries.len(), 1);
        let e = m.find_dual_oracle(2, 3, 4).expect("entry");
        assert_eq!(e.m, 6);
        assert!(m.find_dual_oracle(2, 3, 5).is_none());
        assert!(m.path_of(e).ends_with("x.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_manifest_errors() {
        let dir = std::env::temp_dir().join("grpot-no-such-dir-xyz");
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn malformed_manifest_errors() {
        let dir = std::env::temp_dir().join(format!("grpot-badmani-{}", std::process::id()));
        write_manifest(&dir, r#"{"entries": [{"name": "x"}]}"#);
        assert!(Manifest::load(&dir).is_err());
        write_manifest(&dir, "not json");
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
