//! Process-wide metrics: named counters, timers, gauges, windowed
//! histograms and fixed-bucket histograms with JSON snapshots. Shared
//! across the sweep scheduler, the serving engine and the TCP service
//! (all atomic / lock-protected; cheap enough for per-request use).
//!
//! Locking discipline: counters live in a **read-mostly registry** — an
//! `RwLock` map of `Arc<AtomicU64>` cells. The hot path (`incr` on an
//! existing name) takes the read lock and a relaxed `fetch_add`; the
//! write lock is taken only the first time a name appears, and the
//! serving engine pre-registers its full metric surface at startup so
//! steady-state traffic never writes the map at all.

use crate::benchlib::percentile_sorted;
use crate::jsonlite::Value;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// Sliding-window size per histogram: percentiles are computed over the
/// most recent samples only, so a long-lived service reports current
/// tail latency, not its all-time history.
const HIST_WINDOW: usize = 4096;

/// Ring buffer of recent samples plus all-time count and sum.
#[derive(Clone, Debug, Default)]
struct Window {
    samples: Vec<f64>,
    next: usize,
    total: u64,
    /// All-time sum of recorded samples (Prometheus `_sum`).
    sum: f64,
    /// NaN samples rejected at `record` (they would poison percentiles).
    nan_rejected: u64,
}

impl Window {
    fn record(&mut self, v: f64) {
        // A NaN sample must never enter the window: percentile math and
        // the `sorted` comparator both assume ordered values. Count the
        // rejection so a misbehaving producer is visible, not silent.
        if v.is_nan() {
            self.nan_rejected += 1;
            return;
        }
        if self.samples.len() < HIST_WINDOW {
            self.samples.push(v);
        } else {
            self.samples[self.next] = v;
            self.next = (self.next + 1) % HIST_WINDOW;
        }
        self.total += 1;
        self.sum += v;
    }

    /// Ascending copy of the window (one sort serves many percentiles).
    /// `total_cmp` is a total order, so this cannot panic even if the
    /// NaN guard above is ever bypassed.
    fn sorted(&self) -> Option<Vec<f64>> {
        if self.samples.is_empty() {
            return None;
        }
        let mut sorted = self.samples.clone();
        sorted.sort_by(f64::total_cmp);
        Some(sorted)
    }

    fn percentile(&self, p: f64) -> Option<f64> {
        self.sorted().map(|s| percentile_sorted(&s, p))
    }
}

/// Fixed-bucket cumulative histogram (Prometheus `_bucket{le=…}`):
/// per-bucket counts are *non*-cumulative in memory; the renderer
/// accumulates. The implicit `+Inf` bucket is the last slot.
#[derive(Clone, Debug)]
struct FixedHist {
    /// Ascending upper bounds; one extra count slot holds `+Inf`.
    bounds: Vec<f64>,
    counts: Vec<u64>,
    sum: f64,
    total: u64,
}

impl FixedHist {
    fn new(bounds: &[f64]) -> FixedHist {
        let mut bounds: Vec<f64> = bounds.iter().copied().filter(|b| !b.is_nan()).collect();
        bounds.sort_by(f64::total_cmp);
        bounds.dedup();
        let slots = bounds.len() + 1;
        FixedHist { bounds, counts: vec![0; slots], sum: 0.0, total: 0 }
    }

    fn record(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        let idx = self
            .bounds
            .iter()
            .position(|&b| v <= b)
            .unwrap_or(self.bounds.len());
        self.counts[idx] += 1;
        self.sum += v;
        self.total += 1;
    }

    fn to_json(&self) -> Value {
        let mut buckets: Vec<Value> = Vec::with_capacity(self.counts.len());
        for (i, &c) in self.counts.iter().enumerate() {
            let le = self.bounds.get(i).copied().unwrap_or(f64::INFINITY);
            // +Inf serializes as null in jsonlite; the renderer treats a
            // missing/odd `le` as +Inf, so the round trip is lossless.
            let mut b = Value::obj().set("count", c);
            if le.is_finite() {
                b = b.set("le", le);
            }
            buckets.push(b);
        }
        Value::obj().set("buckets", Value::Arr(buckets))
    }
}

/// Exponential bucket bounds: `start, start·factor, …` (`count` bounds;
/// the `+Inf` bucket is implicit). The serving engine's latency
/// histograms use `exp_buckets(1e-4, 2.0, 16)` ≈ 100 µs … 3.3 s.
pub fn exp_buckets(start: f64, factor: f64, count: usize) -> Vec<f64> {
    assert!(start > 0.0 && factor > 1.0, "need start > 0 and factor > 1");
    let mut out = Vec::with_capacity(count);
    let mut b = start;
    for _ in 0..count {
        out.push(b);
        b *= factor;
    }
    out
}

/// A registry of counters, timers, gauges and histograms.
#[derive(Default)]
pub struct Metrics {
    /// Read-mostly: `incr` on a known name is a read lock + relaxed add.
    counters: RwLock<BTreeMap<String, Arc<AtomicU64>>>,
    /// Sum of seconds and sample count per timer name.
    timers: Mutex<BTreeMap<String, (f64, u64)>>,
    /// Last-write-wins instantaneous values (queue depth, cache bytes).
    gauges: Mutex<BTreeMap<String, f64>>,
    /// Recent-window sample distributions (latency percentiles).
    hists: Mutex<BTreeMap<String, Window>>,
    /// Fixed-bucket histograms (Prometheus-style `le` series), fed by
    /// the same `observe_hist` calls once registered.
    buckets: Mutex<BTreeMap<String, FixedHist>>,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Counter cell for `name`, inserting on first use. The fast path
    /// is the read lock; the write lock is taken at most once per name.
    fn counter_cell(&self, name: &str) -> Arc<AtomicU64> {
        if let Some(c) = self.counters.read().unwrap().get(name) {
            return Arc::clone(c);
        }
        let mut map = self.counters.write().unwrap();
        Arc::clone(
            map.entry(name.to_string())
                .or_insert_with(|| Arc::new(AtomicU64::new(0))),
        )
    }

    /// Pre-insert counter names so later `incr` calls never take the
    /// write lock (the engine registers its surface at startup).
    pub fn register_counters(&self, names: &[&str]) {
        let mut map = self.counters.write().unwrap();
        for name in names {
            map.entry((*name).to_string())
                .or_insert_with(|| Arc::new(AtomicU64::new(0)));
        }
    }

    /// Increment a named counter.
    pub fn incr(&self, name: &str, by: u64) {
        self.counter_cell(name).fetch_add(by, Ordering::Relaxed);
    }

    /// Read a counter (0 when unset).
    pub fn get(&self, name: &str) -> u64 {
        self.counters
            .read()
            .unwrap()
            .get(name)
            .map(|c| c.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record a duration sample.
    pub fn observe(&self, name: &str, seconds: f64) {
        let mut map = self.timers.lock().unwrap();
        let e = map.entry(name.to_string()).or_insert((0.0, 0));
        e.0 += seconds;
        e.1 += 1;
    }

    /// Time a closure and record it.
    pub fn time<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.observe(name, t.elapsed().as_secs_f64());
        out
    }

    /// Mean seconds of a timer (None when unset).
    pub fn mean_seconds(&self, name: &str) -> Option<f64> {
        let map = self.timers.lock().unwrap();
        map.get(name).map(|(s, c)| s / (*c).max(1) as f64)
    }

    /// Set an instantaneous gauge value (last write wins).
    pub fn set_gauge(&self, name: &str, value: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), value);
    }

    /// Read a gauge (None when never set).
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Remove a gauge series entirely (reads return None afterwards).
    /// Used for per-key labeled gauges — e.g. the serving engine's
    /// `serve.breaker_state{dataset="…"}` — so the exported series set
    /// stays bounded by the live key set instead of growing forever.
    pub fn remove_gauge(&self, name: &str) {
        self.gauges.lock().unwrap().remove(name);
    }

    /// Register a fixed-bucket histogram under `name` with the given
    /// ascending upper bounds (`+Inf` implicit). Subsequent
    /// `observe_hist(name, …)` calls feed both the percentile window
    /// and the buckets; re-registration is a no-op.
    pub fn register_hist_buckets(&self, name: &str, bounds: &[f64]) {
        self.buckets
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| FixedHist::new(bounds));
    }

    /// Record a sample into a windowed histogram (for percentiles) and,
    /// when buckets are registered under the same name, into the
    /// fixed-bucket histogram too.
    pub fn observe_hist(&self, name: &str, value: f64) {
        let mut map = self.hists.lock().unwrap();
        map.entry(name.to_string()).or_default().record(value);
        drop(map);
        let mut buckets = self.buckets.lock().unwrap();
        if let Some(h) = buckets.get_mut(name) {
            h.record(value);
        }
    }

    /// Time a closure and record the duration into a histogram.
    pub fn time_hist<T>(&self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.observe_hist(name, t.elapsed().as_secs_f64());
        out
    }

    /// Percentile (0–100) over a histogram's recent window.
    pub fn hist_percentile(&self, name: &str, p: f64) -> Option<f64> {
        self.hists.lock().unwrap().get(name).and_then(|w| w.percentile(p))
    }

    /// All-time sample count of a histogram.
    pub fn hist_count(&self, name: &str) -> u64 {
        self.hists.lock().unwrap().get(name).map(|w| w.total).unwrap_or(0)
    }

    /// All-time mean of a histogram's samples (None when empty).
    pub fn hist_mean(&self, name: &str) -> Option<f64> {
        self.hists
            .lock()
            .unwrap()
            .get(name)
            .filter(|w| w.total > 0)
            .map(|w| w.sum / w.total as f64)
    }

    /// NaN samples rejected from a histogram (0 when none or unset).
    pub fn hist_nan_rejected(&self, name: &str) -> u64 {
        self.hists
            .lock()
            .unwrap()
            .get(name)
            .map(|w| w.nan_rejected)
            .unwrap_or(0)
    }

    /// JSON snapshot of every counter, timer, gauge and histogram.
    /// Histograms report p50/p95/p99 over their recent window plus the
    /// all-time count/sum; bucket-registered ones add a `buckets` array
    /// (the shape [`crate::obs::prom::render`] consumes).
    pub fn snapshot(&self) -> Value {
        let mut counters = Value::obj();
        for (k, v) in self.counters.read().unwrap().iter() {
            counters = counters.set(k, v.load(Ordering::Relaxed));
        }
        let mut timers = Value::obj();
        for (k, (s, c)) in self.timers.lock().unwrap().iter() {
            timers = timers.set(
                k,
                Value::obj().set("total_s", *s).set("count", *c).set(
                    "mean_s",
                    if *c > 0 { *s / *c as f64 } else { 0.0 },
                ),
            );
        }
        let mut gauges = Value::obj();
        for (k, v) in self.gauges.lock().unwrap().iter() {
            gauges = gauges.set(k, *v);
        }
        let bucket_map = self.buckets.lock().unwrap();
        let mut hists = Value::obj();
        for (k, w) in self.hists.lock().unwrap().iter() {
            let mut h = Value::obj().set("count", w.total).set("sum", w.sum);
            if w.nan_rejected > 0 {
                h = h.set("nan_rejected", w.nan_rejected);
            }
            if let Some(sorted) = w.sorted() {
                for (label, p) in [("p50", 50.0), ("p95", 95.0), ("p99", 99.0)] {
                    h = h.set(label, percentile_sorted(&sorted, p));
                }
            }
            if let Some(fixed) = bucket_map.get(k) {
                h = h.set("buckets", fixed.to_json().get("buckets").cloned().unwrap());
            }
            hists = hists.set(k, h);
        }
        Value::obj()
            .set("counters", counters)
            .set("timers", timers)
            .set("gauges", gauges)
            .set("hists", hists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.incr("jobs", 1);
        m.incr("jobs", 2);
        assert_eq!(m.get("jobs"), 3);
        assert_eq!(m.get("missing"), 0);
    }

    #[test]
    fn preregistered_counters_report_zero() {
        let m = Metrics::new();
        m.register_counters(&["a", "b"]);
        assert_eq!(m.get("a"), 0);
        let v = m.snapshot();
        assert_eq!(v.get_path(&["counters", "b"]).unwrap().as_usize(), Some(0));
        m.incr("a", 2);
        assert_eq!(m.get("a"), 2);
    }

    #[test]
    fn timers_record_and_average() {
        let m = Metrics::new();
        m.observe("solve", 1.0);
        m.observe("solve", 3.0);
        assert_eq!(m.mean_seconds("solve"), Some(2.0));
        let out = m.time("quick", || 42);
        assert_eq!(out, 42);
        assert!(m.mean_seconds("quick").unwrap() >= 0.0);
    }

    #[test]
    fn snapshot_is_json() {
        let m = Metrics::new();
        m.incr("a", 5);
        m.observe("t", 0.5);
        let v = m.snapshot();
        assert_eq!(v.get_path(&["counters", "a"]).unwrap().as_usize(), Some(5));
        assert!(v.get_path(&["timers", "t", "mean_s"]).is_some());
    }

    #[test]
    fn gauges_last_write_wins() {
        let m = Metrics::new();
        assert_eq!(m.gauge("depth"), None);
        m.set_gauge("depth", 3.0);
        m.set_gauge("depth", 7.0);
        assert_eq!(m.gauge("depth"), Some(7.0));
    }

    #[test]
    fn hist_percentiles_over_window() {
        let m = Metrics::new();
        assert_eq!(m.hist_percentile("lat", 50.0), None);
        for i in 1..=100 {
            m.observe_hist("lat", i as f64);
        }
        assert_eq!(m.hist_count("lat"), 100);
        assert_eq!(m.hist_mean("lat"), Some(50.5));
        let p50 = m.hist_percentile("lat", 50.0).unwrap();
        let p99 = m.hist_percentile("lat", 99.0).unwrap();
        assert!((p50 - 50.5).abs() < 1.0, "p50={p50}");
        assert!(p99 > 98.0 && p99 <= 100.0, "p99={p99}");
        let out = m.time_hist("timed", || 5);
        assert_eq!(out, 5);
        assert_eq!(m.hist_count("timed"), 1);
    }

    #[test]
    fn hist_window_slides() {
        let m = Metrics::new();
        // Overfill the window with low values, then high ones: the
        // window must reflect recent samples.
        for _ in 0..HIST_WINDOW {
            m.observe_hist("w", 1.0);
        }
        for _ in 0..HIST_WINDOW {
            m.observe_hist("w", 100.0);
        }
        assert_eq!(m.hist_count("w"), 2 * HIST_WINDOW as u64);
        assert_eq!(m.hist_percentile("w", 50.0), Some(100.0));
    }

    #[test]
    fn nan_samples_are_rejected_not_recorded() {
        let m = Metrics::new();
        m.observe_hist("h", 1.0);
        m.observe_hist("h", f64::NAN);
        m.observe_hist("h", 3.0);
        assert_eq!(m.hist_count("h"), 2);
        assert_eq!(m.hist_nan_rejected("h"), 1);
        assert_eq!(m.hist_mean("h"), Some(2.0));
        // Percentile math still works — sorted() no longer panics on
        // any input thanks to total_cmp.
        assert!(m.hist_percentile("h", 99.0).unwrap() <= 3.0);
        let v = m.snapshot();
        assert_eq!(
            v.get_path(&["hists", "h", "nan_rejected"]).unwrap().as_usize(),
            Some(1)
        );
    }

    #[test]
    fn fixed_buckets_count_cumulatively_in_snapshot() {
        let m = Metrics::new();
        m.register_hist_buckets("lat", &[0.1, 1.0]);
        for v in [0.05, 0.5, 0.7, 5.0] {
            m.observe_hist("lat", v);
        }
        let v = m.snapshot();
        let buckets = v
            .get_path(&["hists", "lat", "buckets"])
            .and_then(Value::as_arr)
            .expect("buckets");
        assert_eq!(buckets.len(), 3); // 0.1, 1.0, +Inf
        let counts: Vec<u64> = buckets
            .iter()
            .map(|b| b.get("count").and_then(Value::as_usize).unwrap() as u64)
            .collect();
        assert_eq!(counts, vec![1, 2, 1]); // non-cumulative in memory
        assert!(buckets[2].get("le").is_none()); // +Inf slot
        // The prom renderer turns these into a cumulative le-series.
        let text = crate::obs::prom::render(&v);
        assert!(text.contains("grpot_lat_bucket{le=\"+Inf\"} 4"), "{text}");
    }

    #[test]
    fn exp_buckets_grow_geometrically() {
        let b = exp_buckets(0.001, 10.0, 4);
        assert_eq!(b, vec![0.001, 0.01, 0.1, 1.0]);
    }

    #[test]
    fn snapshot_includes_gauges_and_hists() {
        let m = Metrics::new();
        m.set_gauge("g", 2.5);
        for i in 0..10 {
            m.observe_hist("h", i as f64);
        }
        let v = m.snapshot();
        assert_eq!(v.get_path(&["gauges", "g"]).unwrap().as_f64(), Some(2.5));
        assert_eq!(v.get_path(&["hists", "h", "count"]).unwrap().as_usize(), Some(10));
        assert!(v.get_path(&["hists", "h", "p95"]).unwrap().as_f64().unwrap() > 8.0);
    }

    #[test]
    fn concurrent_increments() {
        let m = std::sync::Arc::new(Metrics::new());
        let pool = crate::pool::ThreadPool::new(4);
        for _ in 0..100 {
            let m2 = std::sync::Arc::clone(&m);
            pool.execute(move || m2.incr("hits", 1));
        }
        pool.join();
        assert_eq!(m.get("hits"), 100);
    }
}
