//! Property-testing substrate (`proptest` is unavailable offline).
//!
//! A deliberately small harness: each property runs `cases` times with a
//! deterministic per-case PRNG derived from `(base_seed, case_index)`,
//! so any failure prints the exact case seed and can be replayed with
//! [`replay`]. Generation helpers cover the shapes the OT tests need
//! (vectors, group structures, dual iterates).
//!
//! ```
//! use grpot::testing::{check, Config};
//! check("abs is nonneg", &Config::default(), |rng| {
//!     let x = rng.uniform(-10.0, 10.0);
//!     if x.abs() >= 0.0 { Ok(()) } else { Err(format!("{x}")) }
//! });
//! ```

use crate::rng::Pcg64;

/// Intra-solve thread count for test runs: `GRPOT_TEST_THREADS` (≥ 1),
/// defaulting to 1. `scripts/ci.sh` re-runs the equivalence suites with
/// this set to 4 so the parallel oracle path is exercised on every push
/// — the solves are deterministic in the thread count, so the same
/// assertions must pass untouched.
pub fn env_threads() -> usize {
    std::env::var("GRPOT_TEST_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&t| t >= 1)
        .unwrap_or(1)
}

/// Property-run configuration.
#[derive(Clone, Debug)]
pub struct Config {
    /// Number of random cases.
    pub cases: usize,
    /// Base seed; change to explore a different region.
    pub base_seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, base_seed: 0x5EED_CAFE }
    }
}

impl Config {
    pub fn cases(n: usize) -> Self {
        Config { cases: n, ..Default::default() }
    }
}

/// Derive the per-case rng.
fn case_rng(base_seed: u64, case: usize) -> Pcg64 {
    Pcg64::new_with_stream(base_seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15), case as u64)
}

/// Run a property. `prop` returns `Err(msg)` to fail the case. Panics
/// with the case index + seed on first failure.
pub fn check<F>(name: &str, cfg: &Config, mut prop: F)
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let mut rng = case_rng(cfg.base_seed, case);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed at case {case} (base_seed={:#x}): {msg}\n\
                 replay with grpot::testing::replay({:#x}, {case}, ..)",
                cfg.base_seed, cfg.base_seed
            );
        }
    }
}

/// Re-run a single failing case by `(base_seed, case)`.
pub fn replay<F>(base_seed: u64, case: usize, mut prop: F) -> Result<(), String>
where
    F: FnMut(&mut Pcg64) -> Result<(), String>,
{
    let mut rng = case_rng(base_seed, case);
    prop(&mut rng)
}

// ---------------------------------------------------------------------
// Generators
// ---------------------------------------------------------------------

/// Vector of `n` uniforms in `[lo, hi)`.
pub fn gen_vec(rng: &mut Pcg64, n: usize, lo: f64, hi: f64) -> Vec<f64> {
    (0..n).map(|_| rng.uniform(lo, hi)).collect()
}

/// Vector of `n` standard normals scaled by `scale`.
pub fn gen_normal_vec(rng: &mut Pcg64, n: usize, scale: f64) -> Vec<f64> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

/// Random group sizes: `l` groups with sizes in `[1, max_g]`.
pub fn gen_group_sizes(rng: &mut Pcg64, l: usize, max_g: usize) -> Vec<usize> {
    (0..l).map(|_| 1 + rng.below(max_g)).collect()
}

/// Offsets from sizes: `[0, s0, s0+s1, …]`.
pub fn offsets_from_sizes(sizes: &[usize]) -> Vec<usize> {
    let mut out = Vec::with_capacity(sizes.len() + 1);
    let mut acc = 0;
    out.push(0);
    for &s in sizes {
        acc += s;
        out.push(acc);
    }
    out
}

/// A probability vector of length `n` (strictly positive entries).
pub fn gen_simplex(rng: &mut Pcg64, n: usize) -> Vec<f64> {
    let mut v: Vec<f64> = (0..n).map(|_| rng.exp1() + 1e-9).collect();
    let s: f64 = v.iter().sum();
    for x in &mut v {
        *x /= s;
    }
    v
}

/// Assert two floats are close; returns an `Err` usable inside `check`.
pub fn close(a: f64, b: f64, tol: f64) -> Result<(), String> {
    let scale = 1.0f64.max(a.abs()).max(b.abs());
    if (a - b).abs() <= tol * scale {
        Ok(())
    } else {
        Err(format!("{a} !≈ {b} (tol {tol})"))
    }
}

/// Assert a ≤ b + slack.
pub fn leq(a: f64, b: f64, slack: f64) -> Result<(), String> {
    if a <= b + slack {
        Ok(())
    } else {
        Err(format!("{a} > {b} (+{slack})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check("square nonneg", &Config::cases(32), |rng| {
            let x = rng.normal();
            leq(0.0, x * x, 0.0)
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn check_reports_failure() {
        check("always fails", &Config::cases(3), |_| Err("boom".into()));
    }

    #[test]
    fn replay_reproduces_case() {
        // The same (seed, case) pair must generate identical values.
        let mut seen = Vec::new();
        check("record", &Config { cases: 4, base_seed: 99 }, |rng| {
            seen.push(rng.next_u64());
            Ok(())
        });
        let mut again = Vec::new();
        for case in 0..4 {
            let _ = replay(99, case, |rng| {
                again.push(rng.next_u64());
                Ok(())
            });
        }
        assert_eq!(seen, again);
    }

    #[test]
    fn generators_shapes() {
        let mut rng = Pcg64::new(1);
        assert_eq!(gen_vec(&mut rng, 5, 0.0, 1.0).len(), 5);
        let sizes = gen_group_sizes(&mut rng, 4, 7);
        assert_eq!(sizes.len(), 4);
        assert!(sizes.iter().all(|&s| (1..=7).contains(&s)));
        let off = offsets_from_sizes(&sizes);
        assert_eq!(off.len(), 5);
        assert_eq!(off[0], 0);
        assert_eq!(off[4], sizes.iter().sum::<usize>());
        let p = gen_simplex(&mut rng, 6);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn close_and_leq() {
        assert!(close(1.0, 1.0 + 1e-12, 1e-9).is_ok());
        assert!(close(1.0, 2.0, 1e-9).is_err());
        assert!(leq(1.0, 2.0, 0.0).is_ok());
        assert!(leq(2.0, 1.0, 0.5).is_err());
    }
}
