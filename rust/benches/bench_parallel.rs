//! Intra-solve parallel scaling: threads ∈ {1, 2, 4, 8} × {fast,
//! origin} on the large synthetic problem. Reports seconds per solve
//! and speedup over threads = 1, and *verifies* — in every mode,
//! including CI smoke — that each thread count returns the byte-equal
//! solution, objective and iteration count (the determinism guarantee
//! the pool's ordered chunk reduction provides).
//!
//! Also runs the **fork-join vs persistent** dispatch comparison: the
//! same dense kernel over the same fixed chunks, dispatched once per
//! eval through the PR-3 `thread::scope` fork-join
//! (`pool::forkjoin_map_chunks`, kept off the hot path exactly for
//! this) and through the PR-4 persistent parked worker set — the
//! per-eval spawn/join overhead is the only difference, and the bench
//! asserts the results stay byte-equal while reporting the speedup.
//!
//! Since PR 5 it also emits **SIMD rows**: the same solves with the
//! scalar reference kernels vs the runtime-dispatched vector kernels
//! (`bench_parallel_simd.csv`), byte-equality asserted — the
//! thread-scaling and SIMD speedups compose multiplicatively.
//!
//! Target (recorded in ROADMAP.md next to the bench-serve baseline):
//! ≥ 1.5× wall-clock speedup at 4 threads on the full-size problem.

mod common;

use common::*;
use grpot::benchlib::{report_dir, smoke_mode, Table, Timer};
use grpot::coordinator::config::Method;
use grpot::data::synthetic;
use grpot::ot::dual::{eval_dense_forkjoin, eval_dense_reusing, DenseEvalScratch, DualParams};
use grpot::ot::fastot::{solve_fast_ot, FastOtConfig, FastOtResult};
use grpot::ot::origin::solve_origin;
use grpot::pool::ParallelCtx;
use grpot::rng::Pcg64;
use grpot::simd::{Dispatch, SimdMode};
use grpot::solvers::lbfgs::LbfgsOptions;

/// Iteration cap per solve: long enough that oracle time dominates the
/// measurement, short enough that the 4-point thread grid × reps stays
/// minutes in full mode.
fn bench_iters() -> usize {
    size3(10, 100, 200)
}

fn solve_simd(
    prob: &grpot::ot::dual::OtProblem,
    method: Method,
    threads: usize,
    simd: SimdMode,
) -> FastOtResult {
    let cfg = FastOtConfig {
        gamma: 0.5,
        rho: 0.6,
        threads,
        simd,
        lbfgs: LbfgsOptions { max_iters: bench_iters(), ..Default::default() },
        ..Default::default()
    };
    match method {
        Method::Origin => solve_origin(prob, &cfg),
        _ => solve_fast_ot(prob, &cfg),
    }
}

fn solve(prob: &grpot::ot::dual::OtProblem, method: Method, threads: usize) -> FastOtResult {
    solve_simd(prob, method, threads, SimdMode::Auto)
}

fn main() {
    banner("parallel scaling");
    // Full mode: |L|=64 classes × 10 samples ⇒ m = n = 640, the
    // "large synthetic problem" regime of the scaling criterion.
    let l = size3(4, 24, 64);
    let g = size3(5, 10, 10);
    let pair = synthetic::controlled(l, g, 0x9A11);
    let prob = problem_of(&pair);
    println!("problem: m={} n={} |L|={}", prob.m(), prob.n(), l);

    let thread_grid: Vec<usize> = if smoke_mode() { vec![1, 2] } else { vec![1, 2, 4, 8] };
    let reps = size3(1, 2, 3);

    let mut table = Table::new(
        "parallel scaling (speedup vs threads=1)",
        &["method", "threads", "s/solve", "speedup", "identical"],
    );
    for method in [Method::Fast, Method::Origin] {
        let mut baseline: Option<(FastOtResult, f64)> = None;
        for &threads in &thread_grid {
            // Best-of-reps wall time; the solve result is identical
            // every rep by construction.
            let mut best = f64::INFINITY;
            let mut res: Option<FastOtResult> = None;
            for _ in 0..reps {
                let timer = Timer::start();
                let r = solve(&prob, method, threads);
                best = best.min(timer.elapsed_s());
                res = Some(r);
            }
            let res = res.expect("at least one rep");
            let (speedup, identical) = match &baseline {
                None => (1.0, true),
                Some((b, t1)) => {
                    let same = b.x == res.x
                        && b.dual_objective == res.dual_objective
                        && b.iterations == res.iterations;
                    (t1 / best.max(1e-12), same)
                }
            };
            assert!(
                identical,
                "{} at {threads} threads diverged from the serial solve",
                method.name()
            );
            println!(
                "{:<8} threads={threads} {:>9.4} s/solve speedup={speedup:>5.2}x identical={identical}",
                method.name(),
                best
            );
            if !smoke_mode() && threads == 4 && speedup < 1.5 {
                println!("  !! below the 1.5x target at 4 threads");
            }
            table.row(vec![
                method.name().into(),
                format!("{threads}"),
                format!("{best:.4}"),
                format!("{speedup:.2}"),
                if identical { "ok".into() } else { "MISMATCH".into() },
            ]);
            if baseline.is_none() {
                baseline = Some((res, best));
            }
        }
    }
    table.emit(&report_dir(), "bench_parallel");

    simd_comparison(&prob);
    dispatch_comparison(&prob);
}

/// SIMD rows: scalar reference kernels vs auto dispatch on full solves
/// (threads ∈ {1, 4}), asserting byte-equality and reporting the
/// kernel-level speedup at solve granularity.
fn simd_comparison(prob: &grpot::ot::dual::OtProblem) {
    let auto_name = Dispatch::resolve(SimdMode::Auto).name();
    println!("\n== simd: scalar vs {auto_name} dispatch ==");
    let reps = size3(1, 2, 3);
    let thread_grid: Vec<usize> = if smoke_mode() { vec![1] } else { vec![1, 4] };
    let mut table = Table::new(
        "simd dispatch (speedup vs scalar kernels)",
        &["method", "threads", "simd", "s/solve", "speedup", "identical"],
    );
    for method in [Method::Fast, Method::Origin] {
        for &threads in &thread_grid {
            let mut baseline: Option<(FastOtResult, f64)> = None;
            for mode in [SimdMode::Scalar, SimdMode::Auto] {
                let mut best = f64::INFINITY;
                let mut res: Option<FastOtResult> = None;
                for _ in 0..reps {
                    let timer = Timer::start();
                    let r = solve_simd(prob, method, threads, mode);
                    best = best.min(timer.elapsed_s());
                    res = Some(r);
                }
                let res = res.expect("at least one rep");
                let (speedup, identical) = match &baseline {
                    None => (1.0, true),
                    Some((b, t_scalar)) => {
                        // The full equivalence contract, matching
                        // tests/simd_equivalence.rs: solution bytes,
                        // objective, iteration/outer counts AND every
                        // oracle counter (screening decisions included).
                        let same = b.x == res.x
                            && b.dual_objective == res.dual_objective
                            && b.iterations == res.iterations
                            && b.outer_rounds == res.outer_rounds
                            && b.stats == res.stats;
                        (t_scalar / best.max(1e-12), same)
                    }
                };
                assert!(
                    identical,
                    "{} with {} dispatch diverged from the scalar kernels",
                    method.name(),
                    mode.name()
                );
                let shown = if mode == SimdMode::Auto { auto_name } else { mode.name() };
                println!(
                    "{:<8} threads={threads} simd={shown:<8} {best:>9.4} s/solve \
                     speedup={speedup:>5.2}x identical={identical}",
                    method.name()
                );
                table.row(vec![
                    method.name().into(),
                    format!("{threads}"),
                    shown.into(),
                    format!("{best:.4}"),
                    format!("{speedup:.2}"),
                    if identical { "ok".into() } else { "MISMATCH".into() },
                ]);
                if baseline.is_none() {
                    baseline = Some((res, best));
                }
            }
        }
    }
    table.emit(&report_dir(), "bench_parallel_simd");
}

/// Fork-join vs persistent dispatch on the identical dense kernel:
/// measures µs/eval for both dispatchers and asserts byte-equality.
fn dispatch_comparison(prob: &grpot::ot::dual::OtProblem) {
    println!("\n== dispatch: fork-join vs persistent ==");
    let params = DualParams::new(0.5, 0.6);
    let mut rng = Pcg64::new(0xD15);
    let x: Vec<f64> = (0..prob.dim()).map(|_| rng.uniform(-0.1, 0.15)).collect();
    let evals = size3(5, 100, 400);
    let thread_grid: Vec<usize> = if smoke_mode() { vec![2] } else { vec![2, 4] };

    let mut table = Table::new(
        "per-eval dispatch (fork-join vs persistent pool)",
        &["threads", "us/eval forkjoin", "us/eval persistent", "speedup", "identical"],
    );
    for &threads in &thread_grid {
        let ctx = ParallelCtx::new(threads);
        let mut scratch = DenseEvalScratch::new(prob);
        let mut g_p = vec![0.0; prob.dim()];
        let mut g_f = vec![0.0; prob.dim()];

        // Warm both paths once (pool spawn, page faults) outside timing.
        let (fp, _) = eval_dense_reusing(prob, &params, &x, &mut g_p, &ctx, &mut scratch);
        let (ff, _) = eval_dense_forkjoin(prob, &params, &x, &mut g_f, threads, &mut scratch);
        assert_eq!(fp.to_bits(), ff.to_bits(), "dispatchers diverged on the objective");
        assert_eq!(g_p, g_f, "dispatchers diverged on the gradient");

        let t = Timer::start();
        for _ in 0..evals {
            eval_dense_reusing(prob, &params, &x, &mut g_p, &ctx, &mut scratch);
        }
        let persistent_us = t.elapsed_s() * 1e6 / evals as f64;

        let t = Timer::start();
        for _ in 0..evals {
            eval_dense_forkjoin(prob, &params, &x, &mut g_f, threads, &mut scratch);
        }
        let forkjoin_us = t.elapsed_s() * 1e6 / evals as f64;

        let speedup = forkjoin_us / persistent_us.max(1e-9);
        println!(
            "threads={threads} forkjoin={forkjoin_us:>9.1} us/eval \
             persistent={persistent_us:>9.1} us/eval speedup={speedup:.2}x"
        );
        table.row(vec![
            format!("{threads}"),
            format!("{forkjoin_us:.1}"),
            format!("{persistent_us:.1}"),
            format!("{speedup:.2}"),
            "ok".into(),
        ]);
    }
    table.emit(&report_dir(), "bench_parallel_dispatch");
}
